//! Integration: the columnar key codec preserves SQL semantics.
//!
//! NULL-key behavior (joins never match, GROUP BY groups together),
//! `-0.0`/`0.0` and Int/integral-Float normalization, first-seen group
//! output order, i64 SUM precision, and top-k — each checked on the codec
//! path and differentially against the legacy row-at-a-time path.

use std::sync::Arc;

use snowpark::engine::{run_sql, Catalog, ExecContext};
use snowpark::types::{Column, DataType, Field, RowSet, RowSetBuilder, Schema, Value};
use snowpark::udf::UdfRegistry;
use snowpark::util::rng::Rng;

fn ctx_for(catalog: Arc<Catalog>, vectorized: bool) -> ExecContext {
    ExecContext::new(catalog, Arc::new(UdfRegistry::new())).with_vectorized(vectorized)
}

/// Run `stmt` through the codec path, asserting the legacy row path
/// produces the identical rowset (schema, types, values, and order).
fn check_both(catalog: &Arc<Catalog>, stmt: &str) -> RowSet {
    let vectorized = run_sql(stmt, &ctx_for(catalog.clone(), true))
        .unwrap_or_else(|e| panic!("{stmt}: {e}"));
    let rowwise = run_sql(stmt, &ctx_for(catalog.clone(), false))
        .unwrap_or_else(|e| panic!("{stmt} (rowwise): {e}"));
    assert_eq!(vectorized, rowwise, "codec/rowwise divergence for {stmt}");
    vectorized
}

fn catalog_with_nulls() -> Arc<Catalog> {
    let catalog = Arc::new(Catalog::new());
    let mut b = RowSetBuilder::new(Schema::new(vec![
        Field::new("k", DataType::Int64),
        Field::new("s", DataType::Utf8),
        Field::new("v", DataType::Float64),
    ]));
    let rows = [
        (Value::Int(1), Value::Str("a".into()), Value::Float(10.0)),
        (Value::Null, Value::Str("b".into()), Value::Float(20.0)),
        (Value::Int(2), Value::Null, Value::Float(30.0)),
        (Value::Null, Value::Str("b".into()), Value::Null),
        (Value::Int(1), Value::Str("a".into()), Value::Float(40.0)),
        (Value::Int(2), Value::Null, Value::Null),
    ];
    for (k, s, v) in rows {
        b.push(vec![k, s, v]).unwrap();
    }
    catalog.register("t", b.finish().unwrap());

    let mut d = RowSetBuilder::new(Schema::new(vec![
        Field::new("k", DataType::Int64),
        Field::new("label", DataType::Utf8),
    ]));
    d.push(vec![Value::Int(1), Value::Str("one".into())]).unwrap();
    d.push(vec![Value::Null, Value::Str("null-key".into())]).unwrap();
    d.push(vec![Value::Int(3), Value::Str("three".into())]).unwrap();
    catalog.register("d", d.finish().unwrap());
    catalog
}

#[test]
fn null_join_keys_never_match() {
    let catalog = catalog_with_nulls();
    // t has two NULL-k rows and d has one NULL-k row: none may pair up.
    let rs = check_both(&catalog, "SELECT t.k, d.label FROM t JOIN d ON t.k = d.k");
    assert_eq!(rs.num_rows(), 2); // the two k=1 rows of t
    for i in 0..rs.num_rows() {
        assert_eq!(rs.row(i), vec![Value::Int(1), Value::Str("one".into())]);
    }
}

#[test]
fn null_join_keys_pad_in_left_join() {
    let catalog = catalog_with_nulls();
    let rs = check_both(
        &catalog,
        "SELECT t.v, d.label FROM t LEFT JOIN d ON t.k = d.k",
    );
    // All 6 left rows survive; NULL-k rows get NULL labels.
    assert_eq!(rs.num_rows(), 6);
    assert_eq!(rs.row(1), vec![Value::Float(20.0), Value::Null]);
    assert_eq!(rs.row(3), vec![Value::Null, Value::Null]);
}

#[test]
fn nulls_group_together_in_group_by() {
    let catalog = catalog_with_nulls();
    let rs = check_both(&catalog, "SELECT k, COUNT(*) AS n FROM t GROUP BY k");
    // Groups in first-seen order: 1, NULL, 2 — NULLs form ONE group.
    assert_eq!(rs.num_rows(), 3);
    assert_eq!(rs.row(0), vec![Value::Int(1), Value::Int(2)]);
    assert_eq!(rs.row(1), vec![Value::Null, Value::Int(2)]);
    assert_eq!(rs.row(2), vec![Value::Int(2), Value::Int(2)]);
}

#[test]
fn count_skips_nulls_and_sum_of_all_null_group() {
    let catalog = catalog_with_nulls();
    let rs = check_both(
        &catalog,
        "SELECT s, COUNT(v) AS n, SUM(v) AS sv FROM t GROUP BY s",
    );
    // Groups first-seen: "a", "b", NULL.
    assert_eq!(rs.num_rows(), 3);
    assert_eq!(rs.row(0), vec![Value::Str("a".into()), Value::Int(2), Value::Float(50.0)]);
    assert_eq!(rs.row(1), vec![Value::Str("b".into()), Value::Int(1), Value::Float(20.0)]);
    assert_eq!(rs.row(2), vec![Value::Null, Value::Int(1), Value::Float(30.0)]);
}

#[test]
fn negative_zero_groups_with_zero() {
    let catalog = Arc::new(Catalog::new());
    let t = RowSet::new(
        Schema::new(vec![Field::new("x", DataType::Float64)]),
        vec![Column::from_f64(vec![0.0, -0.0, 1.0, -0.0])],
    )
    .unwrap();
    catalog.register("t", t);
    let rs = check_both(&catalog, "SELECT x, COUNT(*) AS n FROM t GROUP BY x");
    assert_eq!(rs.num_rows(), 2);
    assert_eq!(rs.row(0)[1], Value::Int(3)); // 0.0 and -0.0 together
    assert_eq!(rs.row(1)[1], Value::Int(1));
}

#[test]
fn int_and_integral_float_join_keys_match() {
    let catalog = Arc::new(Catalog::new());
    let l = RowSet::new(
        Schema::new(vec![Field::new("id", DataType::Int64)]),
        vec![Column::from_i64(vec![1, 2, 3])],
    )
    .unwrap();
    let r = RowSet::new(
        Schema::new(vec![
            Field::new("fid", DataType::Float64),
            Field::new("tag", DataType::Utf8),
        ]),
        vec![
            Column::from_f64(vec![1.0, 2.5, 3.0, -0.0]),
            Column::from_strings(vec!["one".into(), "2.5".into(), "three".into(), "zero".into()]),
        ],
    )
    .unwrap();
    catalog.register("l", l);
    catalog.register("r", r);
    let rs = check_both(
        &catalog,
        "SELECT l.id, r.tag FROM l JOIN r ON l.id = r.fid ORDER BY l.id",
    );
    assert_eq!(rs.num_rows(), 2);
    assert_eq!(rs.row(0), vec![Value::Int(1), Value::Str("one".into())]);
    assert_eq!(rs.row(1), vec![Value::Int(3), Value::Str("three".into())]);
}

#[test]
fn group_output_preserves_first_seen_order() {
    let catalog = Arc::new(Catalog::new());
    let t = RowSet::new(
        Schema::new(vec![Field::new("c", DataType::Utf8)]),
        vec![Column::from_strings(
            ["z", "m", "z", "a", "m", "q", "z"].iter().map(|s| s.to_string()).collect(),
        )],
    )
    .unwrap();
    catalog.register("t", t);
    // No ORDER BY: output order is first-seen group order.
    let rs = check_both(&catalog, "SELECT c, COUNT(*) AS n FROM t GROUP BY c");
    let got: Vec<Value> = (0..rs.num_rows()).map(|i| rs.row(i)[0].clone()).collect();
    assert_eq!(
        got,
        vec![
            Value::Str("z".into()),
            Value::Str("m".into()),
            Value::Str("a".into()),
            Value::Str("q".into()),
        ]
    );
}

#[test]
fn sum_keeps_precision_near_i64_max() {
    // Regression for the f64 SUM accumulator: values near i64::MAX >> 8
    // lose low bits in f64; the i64 accumulator must not.
    let catalog = Arc::new(Catalog::new());
    let a = (i64::MAX >> 8) + 3;
    let b = (i64::MAX >> 8) + 5;
    let t = RowSet::new(
        Schema::new(vec![Field::new("x", DataType::Int64)]),
        vec![Column::from_i64(vec![a, b])],
    )
    .unwrap();
    catalog.register("t", t);
    let rs = check_both(&catalog, "SELECT SUM(x) AS s FROM t");
    assert_eq!(rs.row(0)[0], Value::Int(a + b));
    // Sanity: the old f64 path would have rounded this.
    assert_ne!((a as f64 + b as f64) as i64, a + b);
}

#[test]
fn top_k_equals_full_sort_prefix() {
    let catalog = Arc::new(Catalog::new());
    let mut rng = Rng::new(7);
    let n = 5_000;
    let vals: Vec<i64> = (0..n).map(|_| rng.below(500) as i64).collect();
    let ids: Vec<i64> = (0..n as i64).collect();
    let t = RowSet::new(
        Schema::new(vec![
            Field::new("id", DataType::Int64),
            Field::new("v", DataType::Int64),
        ]),
        vec![Column::from_i64(ids), Column::from_i64(vals)],
    )
    .unwrap();
    catalog.register("t", t);
    let full = check_both(&catalog, "SELECT id, v FROM t ORDER BY v DESC, id");
    for k in [0usize, 1, 17, 4_999, 5_000, 9_000] {
        let stmt = format!("SELECT id, v FROM t ORDER BY v DESC, id LIMIT {k}");
        let topk = check_both(&catalog, &stmt);
        assert_eq!(topk, full.slice(0, k.min(n)), "k={k}");
    }
}

#[test]
fn randomized_differential_group_join_sort() {
    // Random tables with NULLs: the codec path and the legacy row path
    // must produce identical rowsets for grouping, joining, and sorting.
    let mut rng = Rng::new(123);
    let catalog = Arc::new(Catalog::new());
    let n = 3_000;
    let mut b = RowSetBuilder::new(Schema::new(vec![
        Field::new("k", DataType::Int64),
        Field::new("s", DataType::Utf8),
        Field::new("f", DataType::Float64),
        Field::new("v", DataType::Int64),
    ]));
    for _ in 0..n {
        let k = if rng.bool(0.1) { Value::Null } else { Value::Int(rng.below(40) as i64) };
        let s = if rng.bool(0.1) {
            Value::Null
        } else {
            Value::Str(format!("s{}", rng.below(25)))
        };
        let f = if rng.bool(0.1) {
            Value::Null
        } else {
            // Integral floats sometimes, to exercise join normalization.
            let x = rng.below(60) as f64;
            Value::Float(if rng.bool(0.5) { x } else { x + 0.5 })
        };
        let v = Value::Int(rng.range_inclusive(-1000, 1000));
        b.push(vec![k, s, f, v]).unwrap();
    }
    catalog.register("t", b.finish().unwrap());

    let mut d = RowSetBuilder::new(Schema::new(vec![
        Field::new("k", DataType::Int64),
        Field::new("w", DataType::Float64),
    ]));
    for i in 0..60 {
        let k = if i % 7 == 0 { Value::Null } else { Value::Int(i) };
        d.push(vec![k, Value::Float(i as f64 * 1.5)]).unwrap();
    }
    catalog.register("d", d.finish().unwrap());

    for stmt in [
        "SELECT k, COUNT(*) AS n, COUNT(s) AS ns, SUM(v) AS sv, AVG(f) AS af, \
         MIN(f) AS lo, MAX(s) AS hi FROM t GROUP BY k",
        "SELECT s, k, SUM(v) AS sv FROM t GROUP BY s, k",
        "SELECT f, COUNT(*) AS n FROM t GROUP BY f",
        "SELECT t.v, d.w FROM t JOIN d ON t.k = d.k",
        "SELECT t.v, d.w FROM t LEFT JOIN d ON t.k = d.k",
        "SELECT t.v, d.w FROM t JOIN d ON t.f = d.k",
        "SELECT v, s FROM t ORDER BY s, v DESC",
        "SELECT v, f FROM t ORDER BY f DESC, v LIMIT 50",
        "SELECT COUNT(*) AS n, SUM(v) AS s, MIN(k) AS lo FROM t",
    ] {
        check_both(&catalog, stmt);
    }
}

#[test]
fn stats_expose_operator_rows_and_timings() {
    let catalog = catalog_with_nulls();
    let ctx = ctx_for(catalog, true);
    let (out, stats) = snowpark::engine::run_sql_with_stats(
        "SELECT k, COUNT(*) AS n FROM t GROUP BY k",
        &ctx,
    )
    .unwrap();
    assert_eq!(stats.rows_scanned, 6);
    assert_eq!(stats.rows_output, out.num_rows() as u64);
    assert_eq!(stats.aggregate.rows_in, 6);
    assert_eq!(stats.aggregate.rows_out, 3);
    assert!(stats.report().contains("scan"));
}
