//! Randomized differential test pinning the analyzer's contract against
//! the real engine, both directions:
//!
//! - **accept ⇒ runnable**: every generated *valid* statement passes
//!   analysis AND executes without error, and the executed output
//!   schema matches the analyzer's inferred schema;
//! - **reject ⇒ broken**: every generated *invalid* statement (exactly
//!   one flaw, planted in an always-evaluated position) is rejected
//!   with the expected code, and execution fails with an error carrying
//!   the **same code** (the kernels raise through the shared
//!   code-carrying constructors) — except `E130`, where the runtime's
//!   documented behavior is to silently mask a non-boolean predicate to
//!   all-false and return zero rows.
//!
//! The flaws live in projections over non-empty input with no
//! row-filtering WHERE, or in the WHERE itself, so the kernels are
//! guaranteed to actually meet the bad operands (per-row type errors
//! only fire on rows that exist). `E121` (zero-argument aggregate) is
//! analyzer-only: the runtime panics on it, which is exactly why the
//! analyzer must catch it first — covered by tests/analyze_diag.rs.

use std::sync::Arc;

use snowpark::engine::{analyze_sql, run_sql, Catalog, ExecContext, Ty};
use snowpark::types::{Column, DataType, Field, RowSet, Schema};
use snowpark::udf::UdfRegistry;
use snowpark::util::rng::Rng;

const ROWS: i64 = 64;

/// 64 fully non-NULL rows of every engine type, so per-row kernels are
/// guaranteed to evaluate every operand.
fn table() -> RowSet {
    RowSet::new(
        Schema::new(vec![
            Field::new("a", DataType::Int64),
            Field::new("b", DataType::Float64),
            Field::new("s", DataType::Utf8),
            Field::new("c", DataType::Bool),
        ]),
        vec![
            Column::from_i64((0..ROWS).collect()),
            Column::from_f64((0..ROWS).map(|i| i as f64 * 0.5).collect()),
            Column::from_strings((0..ROWS).map(|i| format!("s{}", i % 8)).collect()),
            Column::from_bools((0..ROWS).map(|i| i % 2 == 0).collect()),
        ],
    )
    .unwrap()
}

fn context() -> ExecContext {
    let catalog = Arc::new(Catalog::new());
    catalog.register("t", table());
    let mut ctx = ExecContext::new(catalog, Arc::new(UdfRegistry::new()));
    // Sequential single-node: the differential is about semantics, not
    // shapes (shapes are pinned byte-identical elsewhere).
    ctx.parallelism = 1;
    ctx.nodes = 1;
    ctx
}

// ----------------------------------------------------- valid generator

fn pick<'x>(rng: &mut Rng, options: &[&'x str]) -> &'x str {
    options[rng.below(options.len() as u64) as usize]
}

/// A numeric expression (Int64 or Float64) that can never raise.
fn num_expr(rng: &mut Rng, depth: usize) -> String {
    if depth == 0 || rng.below(2) == 0 {
        return pick(rng, &["a", "b", "2", "7", "3.5", "0.25"]).to_string();
    }
    let d = depth - 1;
    match rng.below(7) {
        0 => format!("({} + {})", num_expr(rng, d), num_expr(rng, d)),
        1 => format!("({} - {})", num_expr(rng, d), num_expr(rng, d)),
        2 => format!("({} * {})", num_expr(rng, d), num_expr(rng, d)),
        // Division by zero yields NULL, never an error.
        3 => format!("({} / {})", num_expr(rng, d), num_expr(rng, d)),
        4 => format!("abs({})", num_expr(rng, d)),
        5 => format!("round({}, 1)", num_expr(rng, d)),
        _ => format!("(-{})", num_expr(rng, d)),
    }
}

/// A string expression that can never raise (substr is total on any
/// start/len; concat coerces).
fn str_expr(rng: &mut Rng, depth: usize) -> String {
    if depth == 0 || rng.below(2) == 0 {
        return pick(rng, &["s", "'k'"]).to_string();
    }
    let d = depth - 1;
    match rng.below(5) {
        0 => format!("upper({})", str_expr(rng, d)),
        1 => format!("lower({})", str_expr(rng, d)),
        2 => format!("substr({}, 1, 2)", str_expr(rng, d)),
        3 => format!("({} || 'x')", str_expr(rng, d)),
        _ => format!("concat({}, 'y')", str_expr(rng, d)),
    }
}

/// A boolean expression that can never raise.
fn bool_expr(rng: &mut Rng, depth: usize) -> String {
    if depth == 0 || rng.below(3) == 0 {
        return pick(rng, &["c", "(NOT c)", "(a < 10)", "(b >= 1.5)"]).to_string();
    }
    let d = depth - 1;
    match rng.below(7) {
        0 => format!("({} < {})", num_expr(rng, d), num_expr(rng, d)),
        1 => format!("({} >= {})", num_expr(rng, d), num_expr(rng, d)),
        2 => format!("({} = {})", str_expr(rng, d), str_expr(rng, d)),
        3 => format!("({} AND {})", bool_expr(rng, d), bool_expr(rng, d)),
        4 => format!("({} OR {})", bool_expr(rng, d), bool_expr(rng, d)),
        5 => format!("({} BETWEEN 0 AND 100)", num_expr(rng, d)),
        _ => "(s IN ('s0', 's1', 'k'))".to_string(),
    }
}

/// One random valid query. Shapes: plain projection (optionally
/// filtered/limited), aggregation, order-by, self-join, subquery.
fn valid_query(rng: &mut Rng) -> String {
    match rng.below(5) {
        0 => {
            let mut sql = format!(
                "SELECT {} AS v0, {} AS v1, {} AS v2 FROM t",
                num_expr(rng, 2),
                str_expr(rng, 2),
                bool_expr(rng, 2)
            );
            if rng.below(2) == 0 {
                sql.push_str(&format!(" WHERE {}", bool_expr(rng, 2)));
            }
            if rng.below(2) == 0 {
                sql.push_str(&format!(" LIMIT {}", rng.below(80)));
            }
            sql
        }
        1 => format!(
            "SELECT s, count(*) AS n, sum(a) AS t1, avg({}) AS t2 FROM t GROUP BY s",
            num_expr(rng, 1)
        ),
        // `OR a = 0` keeps row 0 alive: a global aggregate over an
        // empty input yields one all-NULL row whose column type the
        // engine defaults (no values to derive from), which would be a
        // false schema-divergence signal, not a real contract break.
        2 => format!(
            "SELECT min(a) AS lo, max(b) AS hi FROM t WHERE ({}) OR a = 0",
            bool_expr(rng, 2)
        ),
        3 => format!(
            "SELECT a AS x, {} AS y FROM t ORDER BY {} {} LIMIT {}",
            num_expr(rng, 2),
            pick(rng, &["a", "b", "s"]),
            pick(rng, &["ASC", "DESC"]),
            1 + rng.below(16)
        ),
        _ => format!(
            "SELECT k AS out FROM (SELECT {} AS k, b AS unused FROM t) q WHERE k IS NOT NULL",
            num_expr(rng, 2)
        ),
    }
}

// --------------------------------------------------- invalid generator

/// How execution must behave for a planted flaw.
enum Runtime {
    /// `run_sql` errors and the message contains the code string.
    ErrWithCode,
    /// `run_sql` errors (the legacy scan error carries no code).
    ErrAny,
    /// `run_sql` succeeds with zero rows (the E130 misresolve class).
    OkZeroRows,
}

/// One random invalid query: exactly one flaw, always evaluated.
/// Returns (sql, expected analyzer code, runtime expectation).
fn invalid_query(rng: &mut Rng) -> (String, &'static str, Runtime) {
    // A valid padding projection keeps the statements varied without
    // adding a second flaw or filtering any row.
    let pad = num_expr(rng, 1);
    match rng.below(13) {
        0 => (format!("SELECT {pad} AS p, nope AS bad FROM t"), "E001", Runtime::ErrWithCode),
        1 => (
            // Every column name collides with itself across the
            // self-join, so the bare reference is ambiguous.
            "SELECT b FROM t JOIN t AS t2 ON t.a = t2.a".to_string(),
            "E002",
            Runtime::ErrWithCode,
        ),
        2 => (format!("SELECT {pad} AS p FROM no_such_table"), "E003", Runtime::ErrAny),
        3 => (format!("SELECT {pad} AS p, wat({pad}) AS bad FROM t"), "E004", Runtime::ErrWithCode),
        4 => (format!("SELECT {pad} AS p, ({pad} + s) AS bad FROM t"), "E101", Runtime::ErrWithCode),
        5 => (format!("SELECT a FROM t WHERE {pad} = s"), "E102", Runtime::ErrWithCode),
        6 => ("SELECT a FROM t WHERE c AND s".to_string(), "E103", Runtime::ErrWithCode),
        7 => (format!("SELECT {pad} AS p, (NOT s) AS bad FROM t"), "E104", Runtime::ErrWithCode),
        8 => (format!("SELECT {pad} AS p, (-s) AS bad FROM t"), "E105", Runtime::ErrWithCode),
        9 => (
            format!("SELECT a FROM t WHERE {pad} BETWEEN 1 AND 'z'"),
            "E106",
            Runtime::ErrWithCode,
        ),
        10 => match rng.below(2) {
            0 => (format!("SELECT {pad} AS p, substr(s) AS bad FROM t"), "E110", Runtime::ErrWithCode),
            _ => (format!("SELECT {pad} AS p, upper({pad}) AS bad FROM t"), "E111", Runtime::ErrWithCode),
        },
        11 => (format!("SELECT {pad} AS p, sum(s) AS bad FROM t"), "E120", Runtime::ErrWithCode),
        _ => match rng.below(2) {
            0 => (format!("SELECT a FROM t WHERE {pad} + 1"), "E130", Runtime::OkZeroRows),
            _ => ("SELECT a FROM t WHERE s".to_string(), "E130", Runtime::OkZeroRows),
        },
    }
}

// ------------------------------------------------------------- the test

#[test]
fn accepted_statements_execute_and_match_the_inferred_schema() {
    let ctx = context();
    let udfs = UdfRegistry::new();
    let mut rng = Rng::new(0xD1FF);
    for case in 0..600u64 {
        let mut r = rng.fork(case);
        let sql = valid_query(&mut r);
        let analysis = analyze_sql(&sql, &ctx.catalog, &udfs);
        assert!(
            analysis.is_ok(),
            "case {case}: analyzer rejected a valid statement\n{sql}\n{}",
            analysis.render_errors()
        );
        let out = match run_sql(&sql, &ctx) {
            Ok(out) => out,
            Err(e) => panic!(
                "case {case}: analyzer accepted, engine failed — contract broken\n{sql}\n{e:#}"
            ),
        };
        // Schema differential: the inferred output schema must match
        // what actually executed, name for name and (where the analyzer
        // pinned a type) type for type.
        let names: Vec<&str> = analysis.schema.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, out.schema.names(), "case {case}: schema names diverge\n{sql}");
        if out.num_rows() > 0 {
            for (i, (name, ty)) in analysis.schema.iter().enumerate() {
                if let Ty::Known(dt) = ty {
                    assert_eq!(
                        *dt, out.schema.fields[i].data_type,
                        "case {case}: column {name:?} type diverges\n{sql}"
                    );
                }
            }
        }
    }
}

#[test]
fn rejected_statements_fail_execution_with_the_same_code() {
    let ctx = context();
    let udfs = UdfRegistry::new();
    let mut rng = Rng::new(0xBAD);
    for case in 0..600u64 {
        let mut r = rng.fork(case);
        let (sql, code, runtime) = invalid_query(&mut r);
        let analysis = analyze_sql(&sql, &ctx.catalog, &udfs);
        assert!(
            analysis.errors().any(|d| d.code.as_str() == code),
            "case {case}: expected {code}\n{sql}\ngot: {}",
            analysis.render()
        );
        match runtime {
            Runtime::ErrWithCode => {
                let err = run_sql(&sql, &ctx)
                    .expect_err(&format!("case {case}: engine accepted a {code} statement\n{sql}"));
                let msg = format!("{err:#}");
                assert!(
                    msg.contains(code),
                    "case {case}: runtime error lost its code\n{sql}\nexpected {code} in: {msg}"
                );
            }
            Runtime::ErrAny => {
                run_sql(&sql, &ctx)
                    .expect_err(&format!("case {case}: engine accepted a {code} statement\n{sql}"));
            }
            Runtime::OkZeroRows => {
                let out = run_sql(&sql, &ctx).unwrap_or_else(|e| {
                    panic!("case {case}: E130 must run (misresolve class)\n{sql}\n{e:#}")
                });
                assert_eq!(
                    out.num_rows(),
                    0,
                    "case {case}: non-boolean predicate should mask every row\n{sql}"
                );
            }
        }
    }
}
