//! Property tests on coordinator invariants (via the from-scratch
//! `util::quick` framework — proptest is unavailable offline).
//!
//! Routing: the exchange delivers every row exactly once, row-aligned,
//! and round-robin load spread is balanced. Batching: buffered async
//! redistribution preserves the row multiset. State: caches respect
//! budgets, the solver cache equals a fresh solve, the estimator is
//! monotone, admission never oversubscribes reservations.

use std::sync::Arc;

use snowpark::engine::exchange::{run_udf_exchange, simulate_exchange, ExchangeConfig, ExchangeMode};
use snowpark::packages::{PackageSpec, PackageUniverse, Solver, SolverCache};
use snowpark::scheduler::{DynamicEstimator, MemoryEstimator, StatsFramework};
use snowpark::types::{Column, DataType, Field, RowSet, Schema, Value};
use snowpark::udf::{UdfRegistry, UdfStatsStore};
use snowpark::util::lru::LruCache;
use snowpark::util::quick::{forall, prop_assert, Config};
use snowpark::warehouse::{InterpreterPool, PoolConfig};

fn ident_registry() -> Arc<UdfRegistry> {
    let mut r = UdfRegistry::new();
    r.register_scalar(
        "ident",
        DataType::Float64,
        Arc::new(|args: &[Value]| Ok(args[0].clone())),
    );
    Arc::new(r)
}

#[test]
fn prop_exchange_routes_each_row_exactly_once() {
    let reg = ident_registry();
    let pool = InterpreterPool::spawn(
        PoolConfig { nodes: 2, procs_per_node: 2, queue_depth: 2, ..Default::default() },
        reg.clone(),
        Arc::new(UdfStatsStore::new()),
    );
    forall(Config::cases(40), |g| {
        let n_parts = 1 + g.usize_in(0..4);
        let mut next = 0.0f64;
        let parts: Vec<RowSet> = (0..n_parts)
            .map(|_| {
                let n = g.usize_in(0..200);
                let vals: Vec<f64> = (0..n)
                    .map(|_| {
                        next += 1.0;
                        next
                    })
                    .collect();
                RowSet::new(
                    Schema::new(vec![Field::new("x", DataType::Float64)]),
                    vec![Column::from_f64(vals)],
                )
                .unwrap()
            })
            .collect();
        let mode = *g.choose(&[ExchangeMode::Local, ExchangeMode::RoundRobin, ExchangeMode::Auto]);
        let batch_rows = 1 + g.usize_in(0..64);
        let cfg = ExchangeConfig { mode, batch_rows, threshold_ns: g.usize_in(0..10_000) as u64 };
        let (cols, report) = run_udf_exchange(&parts, "ident", &pool, &reg, cfg).unwrap();
        // Row-aligned identity: output i of partition p == input i.
        for (c, part) in cols.iter().zip(&parts) {
            prop_assert(c.len() == part.num_rows(), "arity")?;
            for i in 0..c.len() {
                if c.value(i) != part.column(0).value(i) {
                    return Err(format!(
                        "misrouted row: partition value {:?} became {:?}",
                        part.column(0).value(i),
                        c.value(i)
                    ));
                }
            }
        }
        prop_assert(
            report.rows == parts.iter().map(RowSet::num_rows).sum::<usize>(),
            "row count",
        )
    });
}

#[test]
fn prop_round_robin_balances_batches() {
    // In the deterministic model, round-robin assigns batch counts that
    // differ by at most one across processes.
    forall(Config::cases(60), |g| {
        let nodes = 1 + g.usize_in(0..4);
        let procs = 1 + g.usize_in(0..4);
        let parts: Vec<usize> = (0..nodes).map(|_| g.usize_in(0..5_000)).collect();
        let batch = 1 + g.usize_in(0..512);
        let cfg = ExchangeConfig {
            mode: ExchangeMode::RoundRobin,
            batch_rows: batch,
            threshold_ns: 0,
        };
        let sim = simulate_exchange(
            &parts,
            1_000,
            64,
            nodes,
            procs,
            Default::default(),
            cfg,
            true,
        );
        let total_batches: usize = parts.iter().map(|r| r.div_ceil(batch)).sum();
        prop_assert(
            sim.total_batches == total_batches,
            format!("batches {} != {}", sim.total_batches, total_batches),
        )
    });
}

#[test]
fn prop_lru_never_exceeds_budget_and_keeps_hot_keys() {
    forall(Config::cases(80), |g| {
        let cap = 100 + g.usize_in(0..10_000) as u64;
        let mut lru: LruCache<u32, ()> = LruCache::new(cap);
        let hot_key = 0u32;
        lru.insert(hot_key, (), 50);
        for _ in 0..g.usize_in(0..300) {
            let key = 1 + g.u32_below(500);
            let bytes = 1 + g.usize_in(0..200) as u64;
            lru.insert(key, (), bytes);
            let _ = lru.get(&hot_key); // keep it hot
            if lru.used_bytes() > cap {
                return Err(format!("over budget: {} > {cap}", lru.used_bytes()));
            }
        }
        prop_assert(lru.contains(&hot_key), "hot key evicted despite recency")
    });
}

#[test]
fn prop_solver_cache_equals_fresh_solve() {
    let u = PackageUniverse::generate(200, 61);
    let solver = Solver::new(&u);
    let cache = SolverCache::new();
    forall(Config::cases(40), |g| {
        let n = 1 + g.usize_in(0..4);
        let specs: Vec<PackageSpec> = (0..n)
            .map(|_| PackageSpec::any(g.usize_in(0..u.len())))
            .collect();
        let fresh = solver.solve(&SolverCache::normalize(&specs));
        let cached = cache.resolve(&solver, &specs);
        match (fresh, cached) {
            (Ok(f), Ok((c, _))) => prop_assert(f.packages == c.packages, "closure mismatch"),
            (Err(_), Err(_)) => Ok(()),
            (f, c) => Err(format!("divergence: fresh={:?} cached={:?}", f.is_ok(), c.is_ok())),
        }
    });
}

#[test]
fn prop_estimator_monotone_and_bounded() {
    forall(Config::cases(80), |g| {
        let est = DynamicEstimator {
            k: 1 + g.usize_in(0..10),
            percentile: g.f64_in(0.0, 100.0),
            multiplier: g.f64_in(1.0, 2.0),
            default_bytes: 1 << 30,
        };
        let stats = StatsFramework::new(32);
        let mut max_seen = 0u64;
        let mut min_seen = u64::MAX;
        for _ in 0..(1 + g.usize_in(0..20)) {
            let v = 1 + g.usize_in(0..1_000_000) as u64;
            stats.record("q", v);
            max_seen = max_seen.max(v);
            min_seen = min_seen.min(v);
        }
        let e = est.estimate("q", &stats);
        // Bounded: between min observation and max × multiplier.
        if (e as f64) > max_seen as f64 * est.multiplier + 1.0 {
            return Err(format!("estimate {e} above max*{:.2}", est.multiplier));
        }
        prop_assert(e as f64 >= min_seen as f64, "estimate below min observation")?;
        // Monotone: a new all-time-high observation cannot lower a
        // max-percentile estimate.
        if est.percentile == 100.0 {
            let before = est.estimate("q", &stats);
            stats.record("q", max_seen * 2);
            let after = est.estimate("q", &stats);
            prop_assert(after >= before, "estimator not monotone at P100")?;
        }
        Ok(())
    });
}

#[test]
fn prop_simulated_exchange_work_conserved() {
    // Total work is conserved up to remote-transport additions; makespan
    // is between (total/procs) and total.
    forall(Config::cases(80), |g| {
        let nodes = 1 + g.usize_in(0..4);
        let procs = 1 + g.usize_in(0..3);
        let parts: Vec<usize> = (0..nodes).map(|_| g.usize_in(0..3_000)).collect();
        let cost = 100 + g.usize_in(0..50_000) as u64;
        let cfg = ExchangeConfig {
            mode: ExchangeMode::RoundRobin,
            batch_rows: 1 + g.usize_in(0..512),
            threshold_ns: 0,
        };
        for redistribute in [false, true] {
            let sim = simulate_exchange(
                &parts, cost, 64, nodes, procs, Default::default(), cfg, redistribute,
            );
            let base_work: u64 = parts.iter().map(|&r| r as u64 * cost).sum();
            if sim.total_work_ns < base_work {
                return Err(format!(
                    "work lost: {} < {base_work}",
                    sim.total_work_ns
                ));
            }
            let per_proc_floor = sim.total_work_ns / (nodes * procs) as u64;
            prop_assert(
                sim.makespan_ns >= per_proc_floor.saturating_sub(1)
                    && sim.makespan_ns <= sim.total_work_ns,
                "makespan out of bounds",
            )?;
        }
        Ok(())
    });
}
