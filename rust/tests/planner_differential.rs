//! Seeded differential suite for the cost-based plan rewriter: every
//! generated statement must produce the SAME bytes with the rewriter on
//! as the unoptimized lowering produces, at every engine shape. The
//! rewrite rules (constant-predicate elimination, predicate pushdown
//! through projections and below joins, selective-predicate scan
//! embedding, projection pruning, join build-side swap) are pure plan
//! transformations — this suite is the executable proof that they never
//! change results, only where the work happens.
//!
//! The generator leans on the engine's documented totality boundaries:
//! numeric arithmetic and comparisons are total over non-NULL Int64 /
//! Float64 data (division by zero yields NULL, never an error), string
//! columns only appear under equality / IN / GROUP BY (string
//! arithmetic is value-dependent and would make "same Ok/Err" a
//! different contract), and the data contains no NaN (NaN comparisons
//! raise). The fact table crosses `MORSEL_MIN_ROWS` so the
//! scan-embedding gate is actually reachable, and the join statements
//! put the big table on the right so the build-side swap fires.

use std::sync::Arc;

use snowpark::engine::{run_sql, run_sql_with_stats, Catalog, ExecContext, MORSEL_MIN_ROWS};
use snowpark::types::{Column, DataType, Field, RowSet, Schema};
use snowpark::udf::UdfRegistry;
use snowpark::util::rng::Rng;

/// Fact-table rows: past the morsel floor so rewrites that gate on
/// "worth parallelizing" (scan embedding) are reachable.
const ROWS: i64 = (MORSEL_MIN_ROWS + 512) as i64;

/// The four shapes every statement is pinned at (nodes, parallelism).
const SHAPES: [(usize, usize); 4] = [(1, 1), (1, 8), (2, 4), (4, 2)];

fn catalog() -> Arc<Catalog> {
    let catalog = Arc::new(Catalog::new());
    // `t`: the fact table. No NaN anywhere; `g` is a 64-ary join key.
    catalog.register(
        "t",
        RowSet::new(
            Schema::new(vec![
                Field::new("a", DataType::Int64),
                Field::new("b", DataType::Float64),
                Field::new("g", DataType::Int64),
                Field::new("s", DataType::Utf8),
                Field::new("c", DataType::Bool),
            ]),
            vec![
                Column::from_i64((0..ROWS).collect()),
                Column::from_f64((0..ROWS).map(|i| i as f64 * 0.5).collect()),
                Column::from_i64((0..ROWS).map(|i| i % 64).collect()),
                Column::from_strings((0..ROWS).map(|i| format!("s{}", i % 8)).collect()),
                Column::from_bools((0..ROWS).map(|i| i % 3 == 0).collect()),
            ],
        )
        .unwrap(),
    );
    // `small`: a dimension table — joins that put `t` on the right of
    // `small` are the build-side-swap cases.
    catalog.register(
        "small",
        RowSet::new(
            Schema::new(vec![
                Field::new("k", DataType::Int64),
                Field::new("w", DataType::Float64),
            ]),
            vec![
                Column::from_i64((0..64).collect()),
                Column::from_f64((0..64).map(|i| i as f64 * 1.25).collect()),
            ],
        )
        .unwrap(),
    );
    catalog
}

fn context(catalog: Arc<Catalog>, nodes: usize, parallelism: usize, rewrite: bool) -> ExecContext {
    let mut ctx = ExecContext::new(catalog, Arc::new(UdfRegistry::new())).with_rewrite(rewrite);
    ctx.nodes = nodes;
    ctx.parallelism = parallelism;
    ctx
}

// ----------------------------------------------------------- generator

fn pick<'x>(rng: &mut Rng, options: &[&'x str]) -> &'x str {
    options[rng.below(options.len() as u64) as usize]
}

/// A total numeric expression over t's columns (division yields NULL on
/// zero, never an error; no string operands).
fn num_expr(rng: &mut Rng, depth: usize) -> String {
    if depth == 0 || rng.below(2) == 0 {
        return pick(rng, &["a", "b", "g", "3", "11", "0.5", "2.25"]).to_string();
    }
    let d = depth - 1;
    match rng.below(6) {
        0 => format!("({} + {})", num_expr(rng, d), num_expr(rng, d)),
        1 => format!("({} - {})", num_expr(rng, d), num_expr(rng, d)),
        2 => format!("({} * {})", num_expr(rng, d), num_expr(rng, d)),
        3 => format!("({} / {})", num_expr(rng, d), num_expr(rng, d)),
        4 => format!("abs({})", num_expr(rng, d)),
        _ => format!("(-{})", num_expr(rng, d)),
    }
}

/// A total boolean expression; strings only under equality / IN.
fn bool_expr(rng: &mut Rng, depth: usize) -> String {
    if depth == 0 || rng.below(3) == 0 {
        return pick(
            rng,
            &["c", "(NOT c)", "(a < 900)", "(b >= 1.5)", "(s = 's3')", "(s IN ('s0', 's5'))"],
        )
        .to_string();
    }
    let d = depth - 1;
    match rng.below(6) {
        0 => format!("({} < {})", num_expr(rng, d), num_expr(rng, d)),
        1 => format!("({} >= {})", num_expr(rng, d), num_expr(rng, d)),
        2 => format!("({} AND {})", bool_expr(rng, d), bool_expr(rng, d)),
        3 => format!("({} OR {})", bool_expr(rng, d), bool_expr(rng, d)),
        4 => format!("({} BETWEEN 0 AND 4000)", num_expr(rng, d)),
        // Constant conjuncts feed the const-elimination rule.
        _ => format!("((1 = 1) AND {})", bool_expr(rng, d)),
    }
}

/// A WHERE predicate: sometimes highly selective (the scan-embedding
/// range), sometimes constant (the elimination rule), usually a random
/// boolean tree.
fn where_pred(rng: &mut Rng) -> String {
    match rng.below(6) {
        // ~2% of rows survive: inside the embedding gate's selectivity
        // ceiling, so the optimized plan filters before shipping.
        0 => format!("b < {}", 40 + rng.below(16)),
        1 => format!("a < {}", 50 + rng.below(50)),
        2 => "1 = 1".to_string(),
        3 => "1 = 0".to_string(),
        _ => bool_expr(rng, 2),
    }
}

/// One random statement. Every shape the planner rewrites appears:
/// filtered scans, projection chains (pruning + pushdown-through-
/// rename), aggregates, sorts, and both join orientations.
fn statement(rng: &mut Rng) -> String {
    match rng.below(8) {
        0 => format!(
            "SELECT a AS x, {} AS y FROM t WHERE {}",
            num_expr(rng, 2),
            where_pred(rng)
        ),
        1 => format!(
            "SELECT x AS out FROM (SELECT a AS x, b AS y, s AS z FROM t) q WHERE x < {}",
            100 + rng.below(400)
        ),
        2 => format!(
            "SELECT s, count(*) AS n, sum({}) AS tot FROM t WHERE {} GROUP BY s",
            num_expr(rng, 1),
            where_pred(rng)
        ),
        3 => format!(
            "SELECT min(a) AS lo, max(b) AS hi FROM t WHERE ({}) OR a = 0",
            bool_expr(rng, 2)
        ),
        4 => format!(
            "SELECT a AS x, b AS y FROM t WHERE {} ORDER BY {} {} LIMIT {}",
            where_pred(rng),
            pick(rng, &["a", "b", "s"]),
            pick(rng, &["ASC", "DESC"]),
            1 + rng.below(32)
        ),
        // Big table on the right: the swap rule builds on `small`.
        5 => format!(
            "SELECT small.w AS w, t.b AS v FROM small JOIN t ON small.k = t.g \
             WHERE t.a < {} ORDER BY v, w LIMIT 64",
            200 + rng.below(800)
        ),
        6 => format!(
            "SELECT t.s AS s, small.w AS w FROM t JOIN small ON t.g = small.k \
             WHERE {} ORDER BY s, w LIMIT 48",
            where_pred(rng)
        ),
        _ => format!(
            "SELECT k AS out FROM (SELECT {} AS k, b AS unused FROM t WHERE {}) q \
             WHERE k IS NOT NULL LIMIT 100",
            num_expr(rng, 2),
            where_pred(rng)
        ),
    }
}

// ------------------------------------------------------------ the tests

/// ≥500 seeded statements × four shapes: the optimized plan's bytes
/// equal the unoptimized lowering's bytes (and errors stay errors).
#[test]
fn rewrites_are_byte_identical_at_every_shape() {
    let catalog = catalog();
    let baseline = context(catalog.clone(), 1, 1, false);
    let optimized: Vec<ExecContext> =
        SHAPES.iter().map(|&(n, p)| context(catalog.clone(), n, p, true)).collect();
    let mut rng = Rng::new(0x9EED);
    for case in 0..520u64 {
        let mut r = rng.fork(case);
        let sql = statement(&mut r);
        let reference = run_sql(&sql, &baseline);
        for (ctx, &(nodes, par)) in optimized.iter().zip(SHAPES.iter()) {
            let got = run_sql(&sql, ctx);
            match (&reference, &got) {
                (Ok(want), Ok(out)) => {
                    assert_eq!(
                        want, out,
                        "case {case} shape ({nodes},{par}): optimized bytes diverge\n{sql}"
                    );
                    // Belt and braces: the rendered bytes too (covers
                    // dtype-sensitive formatting PartialEq could miss).
                    assert_eq!(
                        format!("{want}"),
                        format!("{out}"),
                        "case {case} shape ({nodes},{par}): rendering diverges\n{sql}"
                    );
                }
                (Err(_), Err(_)) => {}
                (Ok(_), Err(e)) => panic!(
                    "case {case} shape ({nodes},{par}): rewrite broke a working statement\n{sql}\n{e:#}"
                ),
                (Err(e), Ok(_)) => panic!(
                    "case {case} shape ({nodes},{par}): rewrite masked an error\n{sql}\n{e:#}"
                ),
            }
        }
    }
}

/// The acceptance gate: on the selective-filter fragment query at two
/// nodes, pushdown strictly reduces the bytes shipped to remote nodes
/// (rows are filtered before their columns go on the wire) while the
/// result stays byte-identical.
#[test]
fn pushdown_strictly_reduces_wire_bytes_at_two_nodes() {
    let catalog = catalog();
    let sql = "SELECT b AS v FROM t WHERE b < 46.0";
    let on = context(catalog.clone(), 2, 2, true);
    let off = context(catalog, 2, 2, false);
    let (rows_on, stats_on) = run_sql_with_stats(sql, &on).unwrap();
    let (rows_off, stats_off) = run_sql_with_stats(sql, &off).unwrap();
    assert_eq!(rows_on, rows_off, "pushdown changed the result bytes");
    assert!(rows_on.num_rows() > 0, "the selective filter should keep some rows");
    let (w_on, w_off) = (stats_on.total_wire_bytes(), stats_off.total_wire_bytes());
    assert!(w_off > 0, "the unoptimized two-node run must actually ship bytes");
    assert!(
        w_on < w_off,
        "pushdown must strictly reduce shipped wire bytes: {w_on} !< {w_off}"
    );
}
