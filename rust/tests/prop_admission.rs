//! Property tests for admission control (via the from-scratch
//! `util::quick` framework — proptest is unavailable offline).
//!
//! Simulation ([`WarehouseScheduler`]): over randomized seeded request
//! streams, every submission gets exactly one outcome (never both
//! admitted and timed out), timed-out waits equal arrival → deadline
//! exactly, and deadlined requests that do run were admitted before
//! their deadline. Online ([`AdmissionGate`]): under a thread fuzz,
//! admitted + timed_out equals submissions and all reservations drain.

use std::collections::HashSet;
use std::sync::Arc;
use std::time::{Duration, Instant};

use snowpark::scheduler::{
    AdmissionConfig, AdmissionGate, AdmissionOutcome, AdmissionPolicy, QueryRequest,
    WarehouseScheduler,
};
use snowpark::util::clock::{Clock, SimClock};
use snowpark::util::ids::QueryId;
use snowpark::util::quick::{forall, prop_assert, prop_eq, Config};

const CAPACITY: u64 = 1_000;

/// A randomized request stream: arrivals sorted ascending, estimates and
/// actuals spanning [tiny, 1.5 × capacity] so placement, queueing, OOM,
/// and the oversized-estimate path all get exercised; ~30 % of requests
/// carry a deadline.
fn random_stream(g: &mut snowpark::util::quick::Gen, n: usize) -> Vec<QueryRequest> {
    let mut arrivals: Vec<u64> = (0..n)
        .map(|_| Duration::from_micros(g.usize_in(0..40_000) as u64).as_nanos() as u64)
        .collect();
    arrivals.sort_unstable();
    arrivals
        .iter()
        .enumerate()
        .map(|(i, &arrival_nanos)| {
            let estimate_bytes = 1 + g.usize_in(0..(CAPACITY as usize * 3 / 2)) as u64;
            let actual_bytes = 1 + g.usize_in(0..(CAPACITY as usize * 3 / 2)) as u64;
            let deadline_nanos = (g.usize_in(0..10) < 3).then(|| {
                arrival_nanos + Duration::from_micros(1 + g.usize_in(0..20_000) as u64).as_nanos() as u64
            });
            QueryRequest {
                id: QueryId(i as u64),
                key: format!("q{i}"),
                estimate_bytes,
                actual_bytes,
                duration: Duration::from_micros(1 + g.usize_in(0..5_000) as u64),
                arrival_nanos,
                deadline_nanos,
            }
        })
        .collect()
}

#[test]
fn prop_every_submission_gets_exactly_one_outcome() {
    forall(Config::cases(20), |g| {
        let n = 5 + g.usize_in(0..40);
        let requests = random_stream(g, n);
        let clock = SimClock::new();
        let mut s = WarehouseScheduler::new(&clock, 1 + g.usize_in(0..4), CAPACITY);
        for q in &requests {
            // Drive the virtual clock to each arrival instant.
            let now = clock.now_nanos();
            if q.arrival_nanos > now {
                clock.sleep(Duration::from_nanos(q.arrival_nanos - now));
            }
            s.submit(q.clone());
        }
        s.run_to_completion();

        prop_eq(s.outcomes().len(), n, "one outcome per submission")?;
        // No request is both admitted and timed out (or double-counted):
        // every id appears exactly once across all outcome kinds.
        let ids: HashSet<u64> = s.outcomes().iter().map(|(id, _)| id.0).collect();
        prop_eq(ids.len(), n, "distinct outcome ids")?;
        let submitted: HashSet<u64> = requests.iter().map(|q| q.id.0).collect();
        prop_assert(ids == submitted, "outcome ids == submitted ids")
    });
}

#[test]
fn prop_deadlines_bound_queue_waits() {
    forall(Config::cases(20), |g| {
        let n = 5 + g.usize_in(0..40);
        let requests = random_stream(g, n);
        let clock = SimClock::new();
        let mut s = WarehouseScheduler::new(&clock, 1 + g.usize_in(0..3), CAPACITY);
        for q in &requests {
            let now = clock.now_nanos();
            if q.arrival_nanos > now {
                clock.sleep(Duration::from_nanos(q.arrival_nanos - now));
            }
            s.submit(q.clone());
        }
        s.run_to_completion();

        let horizon = Duration::from_nanos(clock.now_nanos());
        for (id, outcome) in s.outcomes() {
            let req = &requests[id.0 as usize];
            let budget = req
                .deadline_nanos
                .map(|d| Duration::from_nanos(d.saturating_sub(req.arrival_nanos)));
            match outcome {
                AdmissionOutcome::TimedOut { queue_wait } => {
                    // Timed-out wait is charged arrival → deadline exactly.
                    prop_eq(
                        Some(*queue_wait),
                        budget,
                        &format!("q{} timed-out wait equals its budget", id.0),
                    )?;
                }
                AdmissionOutcome::Completed { queue_wait, .. }
                | AdmissionOutcome::OomKilled { queue_wait, .. } => {
                    // Placed requests were admitted before their deadline…
                    if let Some(b) = budget {
                        prop_assert(
                            *queue_wait <= b,
                            format!("q{}: wait {queue_wait:?} within budget {b:?}", id.0),
                        )?;
                    }
                    // …and no wait can exceed the whole simulated span.
                    prop_assert(
                        *queue_wait <= horizon,
                        format!("q{}: wait {queue_wait:?} within horizon {horizon:?}", id.0),
                    )?;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_undeadlined_streams_never_time_out() {
    forall(Config::cases(10), |g| {
        let n = 5 + g.usize_in(0..30);
        let mut requests = random_stream(g, n);
        for q in &mut requests {
            q.deadline_nanos = None;
        }
        let clock = SimClock::new();
        let mut s = WarehouseScheduler::new(&clock, 2, CAPACITY);
        for q in &requests {
            let now = clock.now_nanos();
            if q.arrival_nanos > now {
                clock.sleep(Duration::from_nanos(q.arrival_nanos - now));
            }
            s.submit(q.clone());
        }
        s.run_to_completion();
        prop_eq(s.timed_out_count(), 0, "no deadline, no timeout")?;
        prop_eq(s.outcomes().len(), n, "everything resolves")
    });
}

/// Thread-fuzz the online gate: every admit() resolves to exactly one of
/// admitted/timed-out, and when all tickets drop the gate drains to zero
/// reservations and an empty queue.
#[test]
fn gate_fuzz_accounts_for_every_request() {
    for (seed, policy) in [(1u64, AdmissionPolicy::Fifo), (2, AdmissionPolicy::Backfill)] {
        let gate = Arc::new(AdmissionGate::new(AdmissionConfig {
            slots: 2,
            capacity_bytes: CAPACITY,
            policy,
        }));
        let threads = 8;
        let per_thread = 25;
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let gate = Arc::clone(&gate);
                std::thread::spawn(move || {
                    let mut rng = snowpark::util::rng::Rng::new(seed * 1000 + t);
                    let mut admitted = 0u64;
                    let mut timed_out = 0u64;
                    for _ in 0..per_thread {
                        let est = 1 + rng.below(CAPACITY * 3 / 2);
                        // Short random deadlines force the timeout path
                        // to interleave with releases.
                        let deadline = rng
                            .bool(0.5)
                            .then(|| Instant::now() + Duration::from_millis(rng.below(8)));
                        match gate.admit(est, deadline) {
                            Ok(ticket) => {
                                admitted += 1;
                                // Hold the slot briefly to create contention.
                                std::thread::sleep(Duration::from_micros(rng.below(300)));
                                drop(ticket);
                            }
                            Err(_) => timed_out += 1,
                        }
                    }
                    (admitted, timed_out)
                })
            })
            .collect();
        let mut admitted = 0u64;
        let mut timed_out = 0u64;
        for h in handles {
            let (a, t) = h.join().expect("fuzz thread panicked");
            admitted += a;
            timed_out += t;
        }
        let total = (threads * per_thread) as u64;
        assert_eq!(admitted + timed_out, total, "{policy:?}: every admit resolves once");
        let counters = gate.counters();
        assert_eq!(counters.admitted, admitted, "{policy:?}: gate agrees on admissions");
        assert_eq!(counters.timed_out, timed_out, "{policy:?}: gate agrees on timeouts");
        assert_eq!(gate.reserved_total(), 0, "{policy:?}: all reservations released");
        assert_eq!(gate.queued(), 0, "{policy:?}: queue drained");
    }
}
