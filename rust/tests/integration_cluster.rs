//! Integration: the cluster path — control plane, init pipeline over a
//! warehouse, recycle semantics, distributed UDF execution through the
//! interpreter pool, and sandbox enforcement on the way.

use std::sync::Arc;

use snowpark::control::{ControlPlane, ControlPlaneConfig, InitRequest};
use snowpark::engine::exchange::ExchangeMode;
use snowpark::packages::{PackageSpec, PackageUniverse};
use snowpark::sandbox::{CgroupLimits, EgressPolicy, Sandbox, Syscall, Verdict};
use snowpark::session::Session;
use snowpark::sim::{register_udfs, TpcxBbDataset, TPCXBB_QUERIES};
use snowpark::types::Value;
use snowpark::util::clock::SimClock;
use snowpark::util::ids::ProcId;
use snowpark::warehouse::{PoolConfig, WarehouseConfig};

#[test]
fn control_plane_lifecycle_and_caching() {
    let universe = Arc::new(PackageUniverse::generate(300, 31));
    let mut cp = ControlPlane::new(universe.clone(), ControlPlaneConfig::default());
    let id = cp.create_warehouse(WarehouseConfig { name: "etl".into(), nodes: 2, ..Default::default() });
    let clock = SimClock::new();
    let specs = vec![
        PackageSpec::any(universe.by_name("numpy").unwrap()),
        PackageSpec::any(universe.by_name("pandas").unwrap()),
    ];
    let pipeline = cp.init_pipeline();
    let req = InitRequest { use_solver_cache: true, use_env_cache: true, node: 0 };

    // Cold → warm → recycle → cold again.
    let mut wh = snowpark::warehouse::VirtualWarehouse::provision(id, WarehouseConfig { nodes: 2, ..Default::default() });
    wh.warm_up(&universe, &snowpark::packages::Prefetcher::new(0, 0));
    let cold = pipeline.run(&specs, &mut wh, req, &clock).unwrap();
    let warm = pipeline.run(&specs, &mut wh, req, &clock).unwrap();
    assert!(!cold.breakdown.env_cache_hit && warm.breakdown.env_cache_hit);
    assert!(warm.breakdown.total_us() < cold.breakdown.total_us());

    wh.recycle_node(0);
    let after = pipeline.run(&specs, &mut wh, req, &clock).unwrap();
    assert!(!after.breakdown.env_cache_hit, "recycle must clear the env cache");
    assert!(after.breakdown.solver_cache_hit, "solver cache is global, survives recycle");
}

#[test]
fn distributed_udf_identical_results_across_modes() {
    let s = Session::builder()
        .pool(PoolConfig { nodes: 3, procs_per_node: 2, ..Default::default() })
        .build()
        .unwrap();
    TpcxBbDataset::generate(1_200, 3, 1.5, 17).register(&s).unwrap();
    let mut reg = s.udfs();
    register_udfs(&mut reg);
    for q in TPCXBB_QUERIES {
        let u = reg.scalar(q.udf).unwrap().clone();
        s.register_scalar_udf(&u.name, u.return_type, u.body.clone());
    }
    let run = |mode| {
        s.reset_pool();
        s.run_distributed_udf("store_sales", "net_margin", &["price", "discount", "quantity"], mode)
            .unwrap()
            .0
    };
    let local = run(ExchangeMode::Local);
    let rr = run(ExchangeMode::RoundRobin);
    assert_eq!(local.len(), rr.len());
    for i in 0..local.len() {
        let a = local.value(i).as_f64().unwrap();
        let b = rr.value(i).as_f64().unwrap();
        assert!((a - b).abs() < 1e-9, "row {i}: {a} vs {b}");
    }
}

#[test]
fn sandboxed_udf_denials_are_audited() {
    // Simulated user code probing the sandbox while a query runs.
    let sb = Sandbox::standard(
        CgroupLimits::default(),
        EgressPolicy::deny_all().allow("api.partner.com", Some(443)),
    );
    // Legit work.
    assert_eq!(sb.check_syscall(ProcId(1), &Syscall::new("read")), Verdict::Allow);
    assert_eq!(
        sb.check_syscall(
            ProcId(1),
            &Syscall::new("openat").with_arg("path", "/sandbox/stage/part0.rs")
        ),
        Verdict::Allow
    );
    // Probing.
    for name in ["ptrace", "mount", "setuid", "init_module"] {
        assert_eq!(sb.check_syscall(ProcId(2), &Syscall::new(name)), Verdict::Deny);
    }
    assert_eq!(sb.supervisor.denials_for(ProcId(2)), 4);
    assert_eq!(sb.supervisor.suspicious_procs(2), vec![ProcId(2)]);
    // Egress through the proxy honors the user policy.
    assert_eq!(
        sb.egress.connect("api.partner.com", 443),
        snowpark::sandbox::EgressDecision::Forwarded
    );
    assert_eq!(
        sb.egress.connect("exfil.evil.io", 443),
        snowpark::sandbox::EgressDecision::Blocked
    );
}

#[test]
fn oom_kill_reaps_only_offender() {
    let sb = Sandbox::standard(
        CgroupLimits { memory_bytes: 1 << 20, cpu_weight: 100, pids_max: 8 },
        EgressPolicy::deny_all(),
    );
    sb.cgroup.charge_memory(ProcId(1), 700 << 10).unwrap();
    let err = sb.cgroup.charge_memory(ProcId(2), 600 << 10);
    assert!(err.is_err());
    assert_eq!(sb.cgroup.oom_kills(), 1);
    assert_eq!(sb.cgroup.memory_used(), 700 << 10); // proc 1 unharmed
}

#[test]
fn udf_stats_feed_redistribution_decision() {
    let s = Session::builder()
        .pool(PoolConfig { nodes: 2, procs_per_node: 2, ..Default::default() })
        .build()
        .unwrap();
    TpcxBbDataset::generate(600, 2, 1.3, 5).register(&s).unwrap();
    s.register_scalar_udf(
        "slowish",
        snowpark::types::DataType::Float64,
        Arc::new(|args: &[Value]| {
            let mut acc = args[0].as_f64().unwrap_or(0.0);
            for i in 0..4_000u64 {
                acc = (acc + i as f64).sqrt() + 1.0;
            }
            Ok(Value::Float(acc))
        }),
    );
    // First run under Auto (no history, est 1µs default → local).
    let (_, r1) = s
        .run_distributed_udf("store_sales", "slowish", &["price"], ExchangeMode::Auto)
        .unwrap();
    assert!(!r1.redistributed);
    // History now shows the true cost; Auto flips to redistribution.
    assert!(s.udf_stats().row_cost_ns("slowish").unwrap() > s.exchange_config().threshold_ns as f64);
    let (_, r2) = s
        .run_distributed_udf("store_sales", "slowish", &["price"], ExchangeMode::Auto)
        .unwrap();
    assert!(r2.redistributed, "history should trigger redistribution");
}
