//! Wire-protocol robustness: every frame kind round-trips through the
//! public codec, and a seeded fuzz loop throws truncated / oversized /
//! garbage byte streams at a *live* server — every hostile input must
//! yield a clean `Error` frame or a closed connection, never a panic or
//! a hang, and the server must keep serving clean traffic afterwards.

use std::io::{self, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use snowpark::engine::Catalog;
use snowpark::server::{
    ErrorKind, Frame, FrameError, ServeClient, ServeReply, Server, ServerConfig, MAX_FRAME_LEN,
};
use snowpark::session::Session;
use snowpark::types::{Column, DataType, Field, RowSet, Schema, WireBatch};
use snowpark::util::rng::Rng;

/// How long a fuzz case may block on a server reply before we call it a
/// hang. Generous for CI; real replies arrive in microseconds.
const HANG_TIMEOUT: Duration = Duration::from_secs(5);

fn sample_rows(n: i64) -> RowSet {
    RowSet::new(
        Schema::new(vec![
            Field::new("id", DataType::Int64),
            Field::new("name", DataType::Utf8),
        ]),
        vec![
            Column::from_i64((0..n).collect()),
            Column::from_strings((0..n).map(|i| format!("row-{i}")).collect()),
        ],
    )
    .unwrap()
}

fn start_server() -> Server {
    let catalog = Arc::new(Catalog::new());
    catalog.register("demo", sample_rows(256));
    Server::start(
        ServerConfig::default(),
        Box::new(move |_tenant| {
            Session::builder().shared_catalog(Arc::clone(&catalog)).build().map(Arc::new)
        }),
    )
    .unwrap()
}

// ---------------------------------------------------------------- codec

#[test]
fn every_frame_kind_round_trips_through_public_codec() {
    let frames = [
        Frame::Hello { tenant: "tenant-a".into() },
        Frame::Hello { tenant: "τenant-ünïcode".into() },
        Frame::Query { sql: "SELECT 1".into(), timeout_ms: 0 },
        Frame::Query { sql: "SELECT * FROM demo WHERE id > 10".into(), timeout_ms: 30_000 },
        Frame::Result { queue_wait_ns: 0, batch: WireBatch::encode(&sample_rows(5)) },
        // Empty result set — zero rows must survive the codec too.
        Frame::Result { queue_wait_ns: u64::MAX, batch: WireBatch::encode(&sample_rows(0)) },
        Frame::Error { kind: ErrorKind::Protocol, message: "bad frame".into() },
        Frame::Error { kind: ErrorKind::AdmissionTimeout, message: String::new() },
        Frame::Error { kind: ErrorKind::DeadlineExceeded, message: "took too long".into() },
        Frame::Error { kind: ErrorKind::Exec, message: "no such table".into() },
        Frame::Error {
            kind: ErrorKind::Semantic,
            message: "error[E001] at Scan(demo): column \"nope\" not found".into(),
        },
    ];
    for frame in &frames {
        let bytes = frame.encode();
        let mut r = io::Cursor::new(bytes.clone());
        let back = Frame::read_from(&mut r).unwrap().unwrap();
        assert_eq!(&back, frame);
        // Re-encoding is byte-stable (the codec is canonical).
        assert_eq!(back.encode(), bytes, "{frame:?}");
    }
    // Frames concatenated on one stream parse back in order.
    let mut wire = Vec::new();
    for frame in &frames {
        wire.extend_from_slice(&frame.encode());
    }
    let mut r = io::Cursor::new(wire);
    for frame in &frames {
        assert_eq!(&Frame::read_from(&mut r).unwrap().unwrap(), frame);
    }
    assert!(Frame::read_from(&mut r).unwrap().is_none(), "clean EOF after last frame");
}

#[test]
fn truncation_at_every_byte_is_malformed_not_panic() {
    let frames = [
        Frame::Hello { tenant: "t".into() },
        Frame::Query { sql: "SELECT id FROM demo".into(), timeout_ms: 9 },
        Frame::Result { queue_wait_ns: 3, batch: WireBatch::encode(&sample_rows(2)) },
        Frame::Error { kind: ErrorKind::Exec, message: "x".into() },
    ];
    for frame in &frames {
        let full = frame.encode();
        for cut in 1..full.len() {
            let mut r = io::Cursor::new(full[..cut].to_vec());
            let err = Frame::read_from(&mut r).unwrap_err();
            assert!(
                matches!(err, FrameError::Malformed(_)),
                "{frame:?} cut at {cut}: {err}"
            );
        }
    }
}

// ------------------------------------------------------------ live fuzz

/// Read replies until the server closes the connection, asserting every
/// frame we do get back is a well-formed reply and nothing blocks past
/// [`HANG_TIMEOUT`]. Returns the number of `Error` frames seen.
fn drain_replies(stream: &TcpStream, ctx: &str) -> usize {
    stream.set_read_timeout(Some(HANG_TIMEOUT)).unwrap();
    let mut reader = io::BufReader::new(stream.try_clone().unwrap());
    let mut errors = 0;
    loop {
        match Frame::read_from(&mut reader) {
            Ok(Some(Frame::Error { .. })) => errors += 1,
            Ok(Some(Frame::Result { .. })) => {}
            Ok(Some(other)) => panic!("{ctx}: server sent a client-side frame {other:?}"),
            Ok(None) => return errors, // clean close
            Err(FrameError::Io(e))
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut =>
            {
                panic!("{ctx}: server hung — no reply within {HANG_TIMEOUT:?}")
            }
            // A hard reset after the server already gave up on us is an
            // acceptable way to learn the connection is gone.
            Err(FrameError::Io(_)) => return errors,
            Err(e) => panic!("{ctx}: server sent unparseable bytes: {e}"),
        }
    }
}

/// Send raw bytes, half-close the write side (so a server blocked on a
/// partial frame sees EOF instead of waiting forever), then drain.
fn poke(addr: std::net::SocketAddr, bytes: &[u8], ctx: &str) -> usize {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).ok();
    // The peer may close before consuming everything; a broken-pipe write
    // is part of the scenario, not a test failure.
    let _ = stream.write_all(bytes);
    let _ = stream.shutdown(Shutdown::Write);
    drain_replies(&stream, ctx)
}

#[test]
fn fuzzed_garbage_yields_error_or_close_never_hang() {
    let server = start_server();
    let addr = server.addr();
    let hello = Frame::Hello { tenant: "fuzz".to_string() }.encode();
    let query = Frame::Query { sql: "SELECT COUNT(*) AS n FROM demo".into(), timeout_ms: 0 }
        .encode();
    let mut rng = Rng::new(0xF0220);

    for case in 0..120u64 {
        let ctx = format!("fuzz case {case}");
        let mut bytes = Vec::new();
        match case % 6 {
            // Pure random bytes as the first frame.
            0 => {
                let n = 1 + rng.below(64) as usize;
                bytes.extend((0..n).map(|_| rng.below(256) as u8));
            }
            // Valid Hello, then random bytes where a Query should be.
            1 => {
                bytes.extend_from_slice(&hello);
                let n = 1 + rng.below(64) as usize;
                bytes.extend((0..n).map(|_| rng.below(256) as u8));
            }
            // Valid Hello, then a truncated (but well-headed) Query.
            2 => {
                bytes.extend_from_slice(&hello);
                let cut = 5 + rng.below((query.len() - 5) as u64) as usize;
                bytes.extend_from_slice(&query[..cut]);
            }
            // Oversized length prefix straight away.
            3 => {
                let huge = (MAX_FRAME_LEN as u32).saturating_add(1 + rng.below(1 << 20) as u32);
                bytes.extend_from_slice(&huge.to_le_bytes());
                bytes.push(rng.below(256) as u8);
            }
            // Zero-length frame after a valid Hello.
            4 => {
                bytes.extend_from_slice(&hello);
                bytes.extend_from_slice(&0u32.to_le_bytes());
            }
            // Valid non-Hello first frame (state-machine violation).
            _ => bytes.extend_from_slice(&query),
        }
        poke(addr, &bytes, &ctx);
    }

    // The server must still serve clean traffic after all that abuse.
    let mut client = ServeClient::connect(addr, "clean").unwrap();
    client.set_read_timeout(Some(HANG_TIMEOUT)).unwrap();
    match client.query("SELECT COUNT(*) AS n FROM demo", 0).unwrap() {
        ServeReply::Rows { rows, .. } => assert_eq!(rows.row(0)[0].as_i64(), Some(256)),
        other => panic!("post-fuzz query failed: {other:?}"),
    }
    drop(client);

    let snap = server.shutdown();
    assert_eq!(snap.worker_panics, 0, "a fuzz input panicked a connection thread");
    assert_eq!(snap.lost(), 0, "unaccounted statements after fuzzing");
    assert!(snap.protocol_errors > 0, "fuzz inputs should register as protocol errors");
    assert_eq!(snap.completed, 1, "exactly the one clean query completes");
}

#[test]
fn hostile_inputs_each_get_a_typed_protocol_error() {
    let server = start_server();
    let addr = server.addr();
    let hello = Frame::Hello { tenant: "t".to_string() }.encode();

    // Each scenario should produce exactly one Error frame, then close.
    let oversized = {
        let mut b = Vec::new();
        b.extend_from_slice(&(MAX_FRAME_LEN as u32 + 1).to_le_bytes());
        b
    };
    let truncated_hello = hello[..hello.len() - 1].to_vec();
    let unknown_tag = {
        let mut b = Vec::new();
        b.extend_from_slice(&hello);
        b.extend_from_slice(&1u32.to_le_bytes());
        b.push(200); // no such tag
        b
    };
    for (bytes, ctx) in [
        (oversized, "oversized prefix"),
        (truncated_hello, "truncated hello"),
        (unknown_tag, "unknown tag after hello"),
    ] {
        let errors = poke(addr, &bytes, ctx);
        assert_eq!(errors, 1, "{ctx}: expected exactly one Error frame");
    }

    let snap = server.shutdown();
    assert_eq!(snap.worker_panics, 0);
    assert_eq!(snap.protocol_errors, 3);
}

#[test]
fn read_timeout_reports_io_not_false_reply() {
    // A silent peer (server accepts, we never send Hello, it never sends
    // anything) must surface as a timeout on our side — this pins down
    // the client behavior the load harness relies on to detect hangs.
    let server = start_server();
    let stream = TcpStream::connect(server.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_millis(100))).unwrap();
    let mut reader = io::BufReader::new(stream.try_clone().unwrap());
    let err = Frame::read_from(&mut reader).unwrap_err();
    match err {
        FrameError::Io(e) => assert!(
            e.kind() == io::ErrorKind::WouldBlock || e.kind() == io::ErrorKind::TimedOut,
            "unexpected io error kind {:?}",
            e.kind()
        ),
        other => panic!("expected Io timeout, got {other}"),
    }
    drop(reader);
    drop(stream);
    server.shutdown();
}
