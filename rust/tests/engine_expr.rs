//! Integration: the columnar expression kernels and the exchange wire
//! codec preserve row-path semantics.
//!
//! - Randomized differential tests: every expression evaluates to the
//!   identical column (schema, types, values, NULL payload normalization)
//!   through the vectorized kernels and the `eval_row` reference path.
//! - Whole-query differentials through `ExecContext::vectorized` on/off,
//!   covering filter/project/join-residual/sort/aggregate expression use.
//! - Columnar exchange round-trips: `WireBatch` encode/decode equals the
//!   per-row `RowSet::row`/`RowSetBuilder` rebuild, including NULLs,
//!   `-0.0`, and empty batches.

use std::sync::Arc;

use snowpark::engine::{
    eval_expr, eval_expr_rowwise, run_sql, Catalog, ExecContext,
};
use snowpark::sql::{parse_query, SelectItem};
use snowpark::types::{
    Column, DataType, Field, RowSet, RowSetBuilder, Schema, Value, WireBatch,
};
use snowpark::udf::UdfRegistry;
use snowpark::util::rng::Rng;

fn parse_expr(sql_expr: &str) -> snowpark::sql::Expr {
    let q = parse_query(&format!("SELECT {sql_expr} FROM t")).unwrap();
    match &q.select[0] {
        SelectItem::Expr { expr, .. } => expr.clone(),
        _ => panic!("expected expression"),
    }
}

/// Random table with NULLs in every column, integral floats (to exercise
/// Int/Float comparison bridging), `-0.0`, empty strings, and negatives.
fn random_table(seed: u64, n: usize) -> RowSet {
    let mut rng = Rng::new(seed);
    let mut b = RowSetBuilder::new(Schema::new(vec![
        Field::new("a", DataType::Int64),
        Field::new("b", DataType::Float64),
        Field::new("s", DataType::Utf8),
        Field::new("t", DataType::Bool),
    ]));
    for _ in 0..n {
        let a = if rng.bool(0.15) {
            Value::Null
        } else {
            Value::Int(rng.range_inclusive(-50, 50))
        };
        let b_v = if rng.bool(0.15) {
            Value::Null
        } else {
            let x = rng.range_inclusive(-40, 40) as f64;
            Value::Float(match rng.below(4) {
                0 => x,
                1 => x + 0.5,
                2 => -0.0,
                _ => x / 3.0,
            })
        };
        let s = if rng.bool(0.15) {
            Value::Null
        } else if rng.bool(0.1) {
            Value::Str(String::new())
        } else {
            Value::Str(format!("s{}", rng.below(20)))
        };
        let t = if rng.bool(0.15) {
            Value::Null
        } else {
            Value::Bool(rng.bool(0.5))
        };
        b.push(vec![a, b_v, s, t]).unwrap();
    }
    b.finish().unwrap()
}

const EXPRS: &[&str] = &[
    "a + 1",
    "a - b",
    "a * a + b / 2.0",
    "b / a",
    "a % 7",
    "a / 0",
    "-a",
    "-b",
    "NOT t",
    "a = 3",
    "a <> 3",
    "b >= 0.0",
    "b = 0.0", // -0.0 must compare equal to 0.0
    "a < b",
    "a = b", // Int/Float comparison bridging
    "s = 'x'",
    "s < 's5'",
    "t = TRUE",
    "s || s",
    "a || '#' || b",
    "t AND a > 1",
    "t OR b > 0.0",
    "(a > 0 AND b > 0.0) OR t",
    "a IS NULL",
    "b IS NOT NULL",
    "a IN (1, 5, NULL)",
    "a NOT IN (2, 4)",
    "s IN ('s1', 's2', 's3')",
    "a BETWEEN -10 AND 10",
    "b NOT BETWEEN -1.0 AND 1.0",
    "a BETWEEN b AND 20",
    "CASE WHEN a > 2 THEN b ELSE -b END",
    "CASE WHEN a > 10 THEN 'big' WHEN a > 0 THEN 'small' END",
    "CASE WHEN t THEN 1 ELSE 2.5 END",
    "CASE WHEN s = 's1' THEN a WHEN s = 's2' THEN a * 2 ELSE 0 END",
    "abs(a)",
    "abs(b)",
    "sqrt(abs(b))",
    "exp(b / 100.0)",
    "floor(b)",
    "ceil(b)",
    "round(b)",
    "round(b, 1)",
    "power(2, a % 5)",
    "upper(s)",
    "lower(s)",
    "length(s)",
    "coalesce(a, 0)",
    "coalesce(NULL, b, 1.0)",
    "coalesce(s, 'fallback')",
    "substr(s, 1, 1)",
    "concat(s, '-', a)",
    "1 + 2 * 3",
    "NULL + 1",
    // NULL-valued constant subtrees stay unfolded so the static type is
    // preserved (Float64 for 1/0, Utf8 for upper(NULL)).
    "1 / 0",
    "1.5 + NULL",
    "upper(NULL)",
    "coalesce(NULL, NULL)",
    // NB: constant expressions here must keep the same output type under
    // static inference (row path on empty input) and folding (vectorized
    // path) — `length` infers Int64, matching its folded value.
    "length('abc') + 1",
];

#[test]
fn randomized_differential_vectorized_vs_eval_row() {
    let reg = UdfRegistry::new();
    for seed in [11u64, 222, 3333] {
        let rs = random_table(seed, 2_000);
        for e in EXPRS {
            let expr = parse_expr(e);
            let vec = eval_expr(&expr, &rs, &reg)
                .unwrap_or_else(|err| panic!("seed {seed}, {e} (vectorized): {err}"));
            let row = eval_expr_rowwise(&expr, &rs, &reg)
                .unwrap_or_else(|err| panic!("seed {seed}, {e} (rowwise): {err}"));
            assert_eq!(vec, row, "seed {seed}: divergence for {e}");
        }
    }
}

#[test]
fn differential_on_empty_input() {
    let reg = UdfRegistry::new();
    let rs = random_table(1, 0);
    for e in EXPRS {
        let expr = parse_expr(e);
        let vec = eval_expr(&expr, &rs, &reg).unwrap();
        let row = eval_expr_rowwise(&expr, &rs, &reg).unwrap();
        assert_eq!(vec, row, "empty input: divergence for {e}");
        assert_eq!(vec.len(), 0);
    }
}

#[test]
fn scalar_udf_differential_with_nulls() {
    let mut reg = UdfRegistry::new();
    reg.register_scalar(
        "halve",
        DataType::Float64,
        Arc::new(|args| match &args[0] {
            Value::Null => Ok(Value::Null),
            v => Ok(Value::Float(v.as_f64().unwrap_or(0.0) / 2.0)),
        }),
    );
    let rs = random_table(77, 1_000);
    for e in ["halve(b)", "halve(a) + 1.0", "halve(coalesce(b, 0.0))"] {
        let expr = parse_expr(e);
        let vec = eval_expr(&expr, &rs, &reg).unwrap();
        let row = eval_expr_rowwise(&expr, &rs, &reg).unwrap();
        assert_eq!(vec, row, "divergence for {e}");
    }
}

fn query_catalog() -> Arc<Catalog> {
    let catalog = Arc::new(Catalog::new());
    catalog.register("t", random_table(5, 1_500));
    let mut d = RowSetBuilder::new(Schema::new(vec![
        Field::new("a", DataType::Int64),
        Field::new("w", DataType::Float64),
    ]));
    for i in -20i64..=20 {
        let k = if i % 6 == 0 { Value::Null } else { Value::Int(i) };
        d.push(vec![k, Value::Float(i as f64 * 0.5)]).unwrap();
    }
    catalog.register("d", d.finish().unwrap());
    catalog
}

/// Whole queries agree between the vectorized and row-at-a-time engines
/// (expressions, residual-before-materialization, aggregates, sort).
#[test]
fn whole_query_differential() {
    let catalog = query_catalog();
    for stmt in [
        "SELECT a + 1 AS a1, b * 2.0 AS b2, upper(s) AS u FROM t WHERE b > 0.0",
        "SELECT a FROM t WHERE s IN ('s1', 's2') AND a IS NOT NULL",
        "SELECT CASE WHEN a > 0 THEN 'pos' ELSE 'neg' END AS sign, COUNT(*) AS n \
         FROM t GROUP BY CASE WHEN a > 0 THEN 'pos' ELSE 'neg' END",
        "SELECT t.a, d.w FROM t JOIN d ON t.a = d.a AND t.b > d.w",
        "SELECT t.a, d.w FROM t LEFT JOIN d ON t.a = d.a AND t.b > d.w",
        "SELECT t.s, d.w FROM t JOIN d ON t.a = d.a AND length(t.s) > 1",
        "SELECT a, b FROM t ORDER BY abs(b) DESC, a LIMIT 40",
        "SELECT s, SUM(a) AS sa, AVG(b) AS ab FROM t GROUP BY s HAVING COUNT(*) > 5",
    ] {
        let on = ExecContext::new(catalog.clone(), Arc::new(UdfRegistry::new()));
        let off = ExecContext::new(catalog.clone(), Arc::new(UdfRegistry::new()))
            .with_vectorized(false);
        let v = run_sql(stmt, &on).unwrap_or_else(|e| panic!("{stmt}: {e}"));
        let r = run_sql(stmt, &off).unwrap_or_else(|e| panic!("{stmt} (rowwise): {e}"));
        assert_eq!(v, r, "query divergence for {stmt}");
    }
}

/// The residual is evaluated pre-materialization; make sure semantics
/// (including constant residuals and qualified column refs) survived.
#[test]
fn residual_join_semantics() {
    let catalog = Arc::new(Catalog::new());
    let l = RowSet::new(
        Schema::new(vec![
            Field::new("k", DataType::Int64),
            Field::new("x", DataType::Int64),
        ]),
        vec![
            Column::from_i64(vec![1, 1, 2, 3]),
            Column::from_i64(vec![10, 20, 30, 40]),
        ],
    )
    .unwrap();
    let r = RowSet::new(
        Schema::new(vec![
            Field::new("k", DataType::Int64),
            Field::new("y", DataType::Int64),
        ]),
        vec![
            Column::from_i64(vec![1, 2, 2]),
            Column::from_i64(vec![15, 25, 35]),
        ],
    )
    .unwrap();
    catalog.register("l", l);
    catalog.register("r", r);
    let ctx = ExecContext::new(catalog.clone(), Arc::new(UdfRegistry::new()));

    // Residual drops the (x=10, y=15) pair and the (x=30, y=35) pair.
    let rs = run_sql(
        "SELECT l.x, r.y FROM l JOIN r ON l.k = r.k AND l.x > r.y ORDER BY l.x, r.y",
        &ctx,
    )
    .unwrap();
    assert_eq!(rs.num_rows(), 2);
    assert_eq!(rs.row(0), vec![Value::Int(20), Value::Int(15)]);
    assert_eq!(rs.row(1), vec![Value::Int(30), Value::Int(25)]);

    // Qualified duplicate column names resolve inside the residual.
    let rs = run_sql(
        "SELECT l.k, r.k FROM l JOIN r ON l.k = r.k AND l.k + r.k > 2",
        &ctx,
    )
    .unwrap();
    assert_eq!(rs.num_rows(), 2); // only the k=2 matches survive

    // Column-free residual conjunct: always-true keeps every match,
    // always-false drops them all.
    let rs = run_sql("SELECT l.x FROM l JOIN r ON l.k = r.k AND 1 < 2", &ctx).unwrap();
    assert_eq!(rs.num_rows(), 4);
    let rs = run_sql("SELECT l.x FROM l JOIN r ON l.k = r.k AND 1 > 2", &ctx).unwrap();
    assert_eq!(rs.num_rows(), 0);

    // Left join: rows whose every match fails the residual are dropped
    // (documented limitation), unmatched left rows keep their NULL pad.
    let rs = run_sql(
        "SELECT l.x, r.y FROM l LEFT JOIN r ON l.k = r.k AND r.y > 100",
        &ctx,
    )
    .unwrap();
    let rowwise = run_sql(
        "SELECT l.x, r.y FROM l LEFT JOIN r ON l.k = r.k AND r.y > 100",
        &ExecContext::new(catalog, Arc::new(UdfRegistry::new())).with_vectorized(false),
    )
    .unwrap();
    assert_eq!(rs, rowwise);
}

/// Vectorized UDFs are callable at the expression level (whole-batch
/// dispatch), and the row path agrees via single-row batches.
#[test]
fn vectorized_udf_in_query() {
    let catalog = Arc::new(Catalog::new());
    catalog.register(
        "t",
        RowSet::new(
            Schema::new(vec![Field::new("x", DataType::Float64)]),
            vec![Column::from_f64(vec![1.0, 2.0, 3.0, 4.0])],
        )
        .unwrap(),
    );
    let mut reg = UdfRegistry::new();
    reg.register_vectorized(
        "vsq",
        DataType::Float64,
        Arc::new(|rows| {
            Ok(rows
                .column(0)
                .f64_data()
                .unwrap()
                .iter()
                .map(|v| v * v)
                .collect())
        }),
    );
    let reg = Arc::new(reg);
    let on = ExecContext::new(catalog.clone(), reg.clone());
    let off = ExecContext::new(catalog, reg).with_vectorized(false);
    let v = run_sql("SELECT vsq(x) AS y FROM t WHERE vsq(x) > 3.0", &on).unwrap();
    let r = run_sql("SELECT vsq(x) AS y FROM t WHERE vsq(x) > 3.0", &off).unwrap();
    assert_eq!(v, r);
    assert_eq!(v.num_rows(), 3);
    assert_eq!(v.row(0)[0], Value::Float(4.0));
}

// ------------------------------------------------------- exchange codec

fn codec_fixture() -> RowSet {
    let mut b = RowSetBuilder::new(Schema::new(vec![
        Field::new("i", DataType::Int64),
        Field::new("f", DataType::Float64),
        Field::new("s", DataType::Utf8),
        Field::new("t", DataType::Bool),
    ]));
    let mut rng = Rng::new(404);
    for k in 0..997 {
        // 997 rows: exercises bitmap tails and uneven final batches.
        let i = if rng.bool(0.2) { Value::Null } else { Value::Int(k) };
        let f = if rng.bool(0.2) {
            Value::Null
        } else if rng.bool(0.1) {
            Value::Float(-0.0)
        } else {
            Value::Float(k as f64 / 7.0)
        };
        let s = if rng.bool(0.2) {
            Value::Null
        } else {
            Value::Str(format!("row-{k}"))
        };
        let t = if rng.bool(0.2) {
            Value::Null
        } else {
            Value::Bool(k % 3 == 0)
        };
        b.push(vec![i, f, s, t]).unwrap();
    }
    b.finish().unwrap()
}

/// Columnar encode/decode must equal the per-row rebuild for every batch
/// of the partition — the differential for the exchange codec.
#[test]
fn wire_codec_matches_perrow_rebuild() {
    let part = codec_fixture();
    let n = part.num_rows();
    for batch_rows in [1usize, 7, 256, 2_000] {
        let mut off = 0;
        while off < n {
            let len = batch_rows.min(n - off);
            // Columnar path.
            let decoded = WireBatch::encode_range(&part, off, len).decode().unwrap();
            // Per-row reference path.
            let sliced = part.slice(off, len);
            let mut b = RowSetBuilder::new(part.schema.clone());
            for r in 0..len {
                b.push(sliced.row(r)).unwrap();
            }
            let rebuilt = b.finish().unwrap();
            assert_eq!(decoded, rebuilt, "batch at {off}+{len} (B={batch_rows})");
            assert_eq!(decoded, sliced, "slice mismatch at {off}+{len}");
            off += len;
        }
    }
}

#[test]
fn wire_codec_preserves_normalization_edges() {
    let rs = RowSet::new(
        Schema::new(vec![
            Field::new("f", DataType::Float64),
            Field::new("i", DataType::Int64),
        ]),
        vec![
            Column::from_f64(vec![-0.0, 0.0, f64::MIN, f64::MAX, 2f64.powi(53) + 2.0]),
            Column::from_i64(vec![i64::MIN, -1, 0, 1, i64::MAX]),
        ],
    )
    .unwrap();
    let decoded = WireBatch::encode(&rs).decode().unwrap();
    assert_eq!(decoded, rs);
    let f = decoded.column(0).f64_data().unwrap();
    assert!(f[0].is_sign_negative() && f[0] == 0.0, "-0.0 sign must survive");
    assert_eq!(decoded.column(1).i64_data().unwrap()[0], i64::MIN);
}

#[test]
fn wire_codec_empty_and_all_null() {
    // Zero rows.
    let empty = RowSet::empty(Schema::new(vec![
        Field::new("x", DataType::Int64),
        Field::new("s", DataType::Utf8),
    ]));
    assert_eq!(WireBatch::encode(&empty).decode().unwrap(), empty);
    // All-NULL column.
    let rs = RowSet::new(
        Schema::new(vec![Field::new("x", DataType::Int64)]),
        vec![Column::Int64 { data: vec![0, 0, 0], valid: Some(vec![false; 3]) }],
    )
    .unwrap();
    let decoded = WireBatch::encode(&rs).decode().unwrap();
    assert_eq!(decoded, rs);
    for i in 0..3 {
        assert_eq!(decoded.column(0).value(i), Value::Null);
    }
}
