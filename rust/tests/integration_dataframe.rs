//! Integration: DataFrame API → SQL emission → engine, checked against
//! equivalent hand-written SQL (the two paths must agree exactly).

use std::sync::Arc;

use snowpark::dataframe::{col, lit};
use snowpark::session::Session;
use snowpark::sim::TpcxBbDataset;

fn session() -> Arc<Session> {
    let s = Session::builder().build().unwrap();
    TpcxBbDataset::generate(1_500, 2, 1.2, 23).register(&s).unwrap();
    s
}

#[test]
fn dataframe_matches_equivalent_sql() {
    let s = session();
    let df = s
        .table("store_sales")
        .filter(col("price").gt(lit(20.0)))
        .group_by(&["item_id"])
        .agg(&[("sum", "quantity", "q"), ("count", "*", "n")])
        .sort("q", true)
        .limit(10)
        .collect()
        .unwrap();
    let sql = s
        .sql(
            "SELECT item_id, SUM(quantity) AS q, COUNT(*) AS n FROM store_sales \
             WHERE price > 20.0 GROUP BY item_id ORDER BY q DESC LIMIT 10",
        )
        .unwrap();
    assert_eq!(df.num_rows(), sql.num_rows());
    for i in 0..df.num_rows() {
        assert_eq!(df.row(i)[1], sql.row(i)[1], "row {i}");
        assert_eq!(df.row(i)[2], sql.row(i)[2], "row {i}");
    }
}

#[test]
fn with_column_then_filter_composes() {
    let s = session();
    let df = s
        .table("store_sales")
        .with_column("rev", col("price").mul(col("quantity")))
        .filter(col("rev").gte(lit(100.0)));
    let n = df.count().unwrap();
    let direct = s
        .sql("SELECT COUNT(*) AS n FROM store_sales WHERE price * quantity >= 100.0")
        .unwrap()
        .row(0)[0]
        .as_i64()
        .unwrap() as usize;
    assert_eq!(n, direct);
}

#[test]
fn join_and_select_cols() {
    let s = session();
    let df = s
        .table("store_sales")
        .join(&s.table("items"), "item_id", "item_id")
        .select_cols(&["category", "price"])
        .limit(20)
        .collect()
        .unwrap();
    assert_eq!(df.schema.names(), vec!["category", "price"]);
    assert!(df.num_rows() <= 20);
}

#[test]
fn emitted_sql_is_reparseable() {
    // Every frame's SQL must round-trip through the parser (the paper's
    // client emits SQL text; the server must accept it).
    let s = session();
    let frames = [
        s.table("items").filter(col("cost").lt(lit(10.0))),
        s.table("store_sales")
            .group_by(&["item_id"])
            .agg(&[("avg", "price", "p")]),
        s.table("store_sales").sort("price", false).limit(3),
        s.table("product_reviews")
            .with_column("len", col("stars").add(lit(1))),
    ];
    for f in &frames {
        snowpark::sql::parse_query(f.to_sql())
            .unwrap_or_else(|e| panic!("emitted SQL not parseable: {} ({e})", f.to_sql()));
        f.collect().unwrap();
    }
}

#[test]
fn count_and_collect_agree() {
    let s = session();
    let df = s.table("web_clickstreams").filter(col("user_id").lt(lit(100)));
    assert_eq!(df.count().unwrap(), df.collect().unwrap().num_rows());
}
