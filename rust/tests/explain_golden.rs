//! EXPLAIN golden snapshots: the optimized physical plan the rewriter
//! produces for every statement the repo actually serves — the serving
//! workload catalog plus one `SELECT udf(...)` statement per TPCx-BB
//! UDF query — rendered in the stable `explain_plan` text format and
//! pinned under `tests/golden/explain/`.
//!
//! A snapshot that drifts means the planner changed its mind about a
//! real workload statement: a new rule fired, an estimate moved across
//! a gate, or a join order flipped. That can be intentional — rerun
//! with the files deleted (the test bootstraps missing snapshots) and
//! commit the diff — but it must never be invisible. The `explain-golden`
//! CI job fails on any uncommitted drift.
//!
//! Everything feeding the text is seeded and deterministic: the dataset
//! generator, the per-table statistics built at registration, and the
//! cost estimates derived from them. No query executes, so the
//! selectivity-feedback loop never perturbs the stats.

use std::path::PathBuf;
use std::sync::Arc;

use snowpark::engine::Catalog;
use snowpark::session::Session;
use snowpark::sim::{register_udfs, TpcxBbDataset, SERVING_CATALOG, TPCXBB_QUERIES};

/// Same dataset shape as the `check-sql --corpus` CI gate.
const ROWS: usize = 1_000;
const SEED: u64 = 7;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/explain")
}

/// The corpus session: merged TPCx-BB catalog plus the simulated UDFs,
/// exactly what the serving layer analyzes against.
fn corpus_session() -> Arc<Session> {
    let catalog = Arc::new(Catalog::new());
    TpcxBbDataset::generate(ROWS, 4, 1.4, SEED).register_merged(&catalog).unwrap();
    let s = Session::builder().shared_catalog(catalog).build().unwrap();
    let mut reg = s.udfs();
    register_udfs(&mut reg);
    for q in TPCXBB_QUERIES {
        let u = reg.scalar(q.udf).unwrap().clone();
        s.register_scalar_udf(&u.name, u.return_type, u.body.clone());
    }
    s
}

/// Every corpus statement as `(snapshot name, sql)`.
fn corpus_statements() -> Vec<(String, String)> {
    let mut statements: Vec<(String, String)> = SERVING_CATALOG
        .iter()
        .map(|stmt| (format!("serving_{}", stmt.name), stmt.sql.to_string()))
        .collect();
    for q in TPCXBB_QUERIES {
        statements.push((
            format!("tpcxbb_{}", q.name),
            format!("SELECT {}({}) AS v FROM {}", q.udf, q.input_cols.join(", "), q.table),
        ));
    }
    statements
}

#[test]
fn corpus_explain_matches_the_golden_snapshots() {
    let s = corpus_session();
    let dir = golden_dir();
    std::fs::create_dir_all(&dir).unwrap();
    let mut bootstrapped = Vec::new();
    let mut drifted = Vec::new();
    for (name, sql) in corpus_statements() {
        let analysis = s.check_sql(&sql);
        assert!(
            analysis.is_ok(),
            "{name}: corpus statement no longer analyzes\n{sql}\n{}",
            analysis.render_errors()
        );
        assert!(
            !analysis.optimized.is_empty(),
            "{name}: analysis carries no optimized plan\n{sql}"
        );
        // `-- <sql>` header so a snapshot is reviewable on its own.
        let rendered = format!("-- {sql}\n{}", analysis.optimized);
        let path = dir.join(format!("{name}.txt"));
        match std::fs::read_to_string(&path) {
            Ok(want) if want == rendered => {}
            Ok(want) => {
                eprintln!(
                    "=== {name}: optimized plan drifted ===\n--- golden\n{want}\n--- current\n{rendered}"
                );
                drifted.push(name);
            }
            Err(_) => {
                std::fs::write(&path, &rendered).unwrap();
                bootstrapped.push(name);
            }
        }
    }
    if !bootstrapped.is_empty() {
        eprintln!(
            "bootstrapped {} snapshot(s): {} — commit tests/golden/explain/",
            bootstrapped.len(),
            bootstrapped.join(", ")
        );
    }
    assert!(
        drifted.is_empty(),
        "optimized plans drifted from their golden snapshots: {} \
         (intentional? delete the files, rerun to bootstrap, commit the diff)",
        drifted.join(", ")
    );
}
