//! Differential tests: morsel-driven parallel execution — across worker
//! threads and warehouse nodes, with and without work stealing — vs the
//! sequential path, plus the exchange-report/makespan-model invariant.
//!
//! Every query must produce an *identical* rowset at every
//! `(nodes, parallelism)` shape — group order, sort order (index
//! tiebreaks), dtypes, and validity representation included. Data is
//! randomized (uniform and Zipf-skewed keys, NULLs in both keys and
//! values), but float values are quarter-integers so summation is exact
//! under any association and bitwise comparison is meaningful.

use std::sync::Arc;

use anyhow::Result;
use snowpark::engine::exchange::{
    run_udf_exchange, simulate_exchange, ExchangeConfig, ExchangeMode,
};
use snowpark::engine::fault::is_deadline_exceeded;
use snowpark::engine::{run_sql, run_sql_with_stats, CancelToken, Catalog, ExecContext, FaultPlan};
use snowpark::scheduler::StatsFramework;
use snowpark::types::{Column, DataType, Field, RowSet, Schema, Value};
use snowpark::udf::{UdafState, UdfRegistry, UdfStatsStore};
use snowpark::util::rng::{Rng, Zipf};
use snowpark::warehouse::{InterpreterPool, PoolConfig, TransportCost};

/// `facts(k BIGINT?, v DOUBLE?, tag VARCHAR)` with randomized keys plus
/// `dim(k BIGINT, label VARCHAR, w DOUBLE)` covering half the key space
/// (so joins have unmatched rows). Values are quarter-integers.
fn catalog(n: usize, n_keys: usize, zipf: Option<f64>, seed: u64) -> Arc<Catalog> {
    let mut rng = Rng::new(seed);
    let mut keys = Vec::with_capacity(n);
    match zipf {
        Some(s) => {
            let z = Zipf::new(n_keys, s);
            for _ in 0..n {
                keys.push(z.sample(&mut rng) as i64);
            }
        }
        None => {
            for _ in 0..n {
                keys.push(rng.below(n_keys as u64) as i64);
            }
        }
    }
    let vals: Vec<f64> = (0..n).map(|_| rng.below(4_000) as f64 / 4.0).collect();
    let vmask: Vec<bool> = (0..n).map(|_| rng.below(8) != 0).collect();
    let kmask: Vec<bool> = (0..n).map(|_| rng.below(50) != 0).collect();
    let tags: Vec<String> = keys.iter().map(|k| format!("tag_{:03}", k % 97)).collect();
    let facts = RowSet::new(
        Schema::new(vec![
            Field::new("k", DataType::Int64),
            Field::new("v", DataType::Float64),
            Field::new("tag", DataType::Utf8),
        ]),
        vec![
            Column::Int64 { data: keys, valid: Some(kmask) },
            Column::Float64 { data: vals, valid: Some(vmask) },
            Column::from_strings(tags),
        ],
    )
    .unwrap();
    let dim_n = n_keys / 2 + 1;
    let dim = RowSet::new(
        Schema::new(vec![
            Field::new("k", DataType::Int64),
            Field::new("label", DataType::Utf8),
            Field::new("w", DataType::Float64),
        ]),
        vec![
            Column::from_i64((0..dim_n as i64).collect()),
            Column::from_strings((0..dim_n).map(|k| format!("label_{k}")).collect()),
            Column::from_f64((0..dim_n).map(|k| (k % 11) as f64).collect()),
        ],
    )
    .unwrap();
    let catalog = Arc::new(Catalog::new());
    catalog.register("facts", facts);
    catalog.register("dim", dim);
    catalog
}

/// Exactly mergeable UDAF (i64 sum of squares): `merge` is associative
/// and exact, so parallel partial aggregation must be bit-identical.
struct SumSq {
    sum: i64,
}

impl UdafState for SumSq {
    fn update(&mut self, args: &[Value]) -> Result<()> {
        if let Some(x) = args[0].as_i64() {
            self.sum += x * x;
        }
        Ok(())
    }
    fn merge(&mut self, other: Box<dyn UdafState>) -> Result<()> {
        let o = other.as_any().downcast_ref::<SumSq>().expect("same UDAF state type");
        self.sum += o.sum;
        Ok(())
    }
    fn finish(&self) -> Result<Value> {
        Ok(Value::Int(self.sum))
    }
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

fn registry() -> Arc<UdfRegistry> {
    let mut r = UdfRegistry::new();
    r.register_udaf("sumsq", DataType::Int64, Arc::new(|| Box::new(SumSq { sum: 0 })));
    r.register_scalar(
        "halve",
        DataType::Float64,
        Arc::new(|args| match &args[0] {
            Value::Null => Ok(Value::Null),
            v => Ok(Value::Float(v.as_f64().unwrap_or(0.0) / 2.0)),
        }),
    );
    Arc::new(r)
}

fn ctx(catalog: Arc<Catalog>, parallelism: usize) -> ExecContext {
    ExecContext::new(catalog, registry()).with_parallelism(parallelism)
}

fn fault_ctx(catalog: Arc<Catalog>, threads: usize, nodes: usize, plan: &str) -> ExecContext {
    ctx(catalog, threads).with_nodes(nodes).with_fault_plan(FaultPlan::parse(plan).unwrap())
}

/// True on the CI chaos leg (a seeded `SNOWPARK_FAULT_PLAN` injects
/// faults into every default `ExecContext`): tests that pin exact
/// wire-byte or retry-counter values skip there — recovery keeps the
/// *outputs* identical, not the transport accounting.
fn chaos_env() -> bool {
    std::env::var("SNOWPARK_FAULT_PLAN").map_or(false, |v| !v.trim().is_empty())
}

const QUERIES: &[&str] = &[
    // Grouped aggregates over int keys (including NULL keys, which group
    // together) and string keys.
    "SELECT k, COUNT(*) AS n, COUNT(v) AS nv, SUM(v) AS s, AVG(v) AS a, \
     MIN(v) AS lo, MAX(v) AS hi FROM facts GROUP BY k",
    "SELECT tag, SUM(k) AS s, MIN(tag) AS t0, MAX(k) AS hi FROM facts GROUP BY tag",
    // Global aggregation plus UDAFs (exact i64 merge).
    "SELECT COUNT(*) AS n, SUM(v) AS s, sumsq(k) AS q FROM facts",
    "SELECT k, sumsq(k) AS q, AVG(v) AS a FROM facts GROUP BY k",
    // Filter → project pipelines (morsel-evaluated expressions, scalar
    // UDF included).
    "SELECT k, v FROM facts WHERE v > 500.0 AND k < 40",
    "SELECT k + 1 AS k1, halve(v) AS h, tag FROM facts",
    // Joins: inner, left (NULL padding), and a residual predicate over
    // both sides.
    "SELECT facts.k, label FROM facts JOIN dim ON facts.k = dim.k",
    "SELECT facts.k, label FROM facts LEFT JOIN dim ON facts.k = dim.k",
    "SELECT facts.k, label FROM facts JOIN dim ON facts.k = dim.k AND v > w * 50.0",
    // Sorts: full sort, and ORDER BY ... LIMIT with heavy ties (97
    // distinct tags), where only the index tiebreak decides.
    "SELECT k, tag, v FROM facts ORDER BY tag, v DESC",
    "SELECT k, tag FROM facts ORDER BY tag LIMIT 23",
    "SELECT k, v FROM facts ORDER BY v DESC, k LIMIT 100",
    // Subquery pipeline (aggregate feeding filter).
    "SELECT tag, n FROM (SELECT tag, COUNT(*) AS n FROM facts GROUP BY tag) t \
     WHERE n > 100",
];

/// Multi-operator shapes that the ISSUE 5 fragment planner fuses into
/// per-node pipeline fragments: scan→filter→project→aggregate,
/// join+residual feeding a computed-projection top-k sort, fused
/// filter+project chains, and empty-survivor edges.
const FRAGMENT_QUERIES: &[&str] = &[
    // The flagship: filter + projection + aggregate partials in ONE
    // shipment per node (every aggregate kind incl. a UDAF).
    "SELECT k2, COUNT(*) AS n, COUNT(vv) AS nv, SUM(vv) AS s, AVG(vv) AS a, \
     MIN(vv) AS lo, MAX(vv) AS hi, sumsq(k2) AS q FROM \
     (SELECT k + 1 AS k2, v * 2.0 AS vv FROM facts WHERE v < 800.0) t GROUP BY k2",
    // Filter directly under the aggregate (no projection stage).
    "SELECT tag, COUNT(*) AS n, MAX(k) AS hi FROM facts WHERE v > 100.0 GROUP BY tag",
    // Global aggregation over a fused chain, including the all-filtered
    // edge (one row out, NULL sums).
    "SELECT COUNT(*) AS n, SUM(vv) AS s FROM \
     (SELECT v * 2.0 AS vv FROM facts WHERE v > 250.0) t",
    "SELECT COUNT(*) AS n, SUM(v) AS s, MIN(v) AS lo FROM facts WHERE v > 99999.0",
    "SELECT tag, COUNT(*) AS n FROM facts WHERE v > 99999.0 GROUP BY tag",
    // join + residual + sort + limit: the probe is its own fragment
    // (breaker: the leader-built build table), the computed projection
    // above it fuses with top-k run generation.
    "SELECT facts.k + 0 AS k2, v * 2.0 AS vv, label FROM facts \
     JOIN dim ON facts.k = dim.k AND v > w * 40.0 ORDER BY vv DESC, k2 LIMIT 60",
    // Capless chain (filter+project, scalar UDF included).
    "SELECT k + 1 AS k1, halve(v) AS h FROM facts WHERE v > 500.0 AND k < 200",
    // Hidden sort column: drop projection runs on the leader.
    "SELECT k + 1 AS k1 FROM facts WHERE v < 700.0 ORDER BY tag, v LIMIT 23",
];

#[test]
fn parallel_matches_sequential_randomized() {
    for (seed, zipf) in [(1u64, None), (2, Some(1.2)), (3, Some(0.8))] {
        let cat = catalog(30_000, 600, zipf, seed);
        for q in QUERIES {
            // Pin the baseline to the exact sequential path even under
            // the CI stress legs' SNOWPARK_NODES env (the candidates
            // deliberately inherit it).
            let seq = run_sql(q, &ctx(cat.clone(), 1).with_nodes(1))
                .unwrap_or_else(|e| panic!("seed {seed}: {q}: {e}"));
            for p in [2usize, 8] {
                let par = run_sql(q, &ctx(cat.clone(), p))
                    .unwrap_or_else(|e| panic!("seed {seed} parallelism {p}: {q}: {e}"));
                assert_eq!(par, seq, "seed {seed} parallelism {p}: {q}");
            }
        }
    }
}

/// The ISSUE 4 acceptance matrix: byte-identical output at
/// `(nodes, threads)` ∈ {(1,1), (1,8), (2,4), (4,2)} over uniform and
/// Zipf-1.2 keys, on every differential query. The (1,1) shape is the
/// exact sequential path; the multi-node shapes ship operator spans
/// through the columnar exchange and work-steal within each node.
#[test]
fn node_shapes_match_sequential_randomized() {
    for (seed, zipf) in [(11u64, None), (12, Some(1.2))] {
        let cat = catalog(30_000, 600, zipf, seed);
        for q in QUERIES {
            let base = run_sql(q, &ctx(cat.clone(), 1).with_nodes(1))
                .unwrap_or_else(|e| panic!("seed {seed}: {q}: {e}"));
            for (nodes, threads) in [(1usize, 8usize), (2, 4), (4, 2)] {
                let out = run_sql(q, &ctx(cat.clone(), threads).with_nodes(nodes))
                    .unwrap_or_else(|e| panic!("seed {seed} ({nodes},{threads}): {q}: {e}"));
                assert_eq!(out, base, "seed {seed} ({nodes},{threads}): {q}");
            }
        }
    }
}

/// The ISSUE 5 acceptance matrix: fragment dispatch must be
/// byte-identical to the legacy operator-at-a-time dispatch AND to the
/// sequential path on multi-operator queries, at every tested
/// `(nodes, parallelism)` shape, over uniform and Zipf-1.2 keys. (Data
/// uses quarter-integer floats so per-morsel partial sums are exact
/// under any association.)
#[test]
fn fragment_dispatch_matches_legacy_randomized() {
    for (seed, zipf) in [(41u64, None), (42, Some(1.2))] {
        let cat = catalog(30_000, 600, zipf, seed);
        for q in FRAGMENT_QUERIES.iter().chain(QUERIES) {
            let base = run_sql(q, &ctx(cat.clone(), 1).with_nodes(1))
                .unwrap_or_else(|e| panic!("seed {seed}: {q}: {e}"));
            for (nodes, threads) in [(1usize, 8usize), (2, 4), (4, 2)] {
                for fragments in [true, false] {
                    let out = run_sql(
                        q,
                        &ctx(cat.clone(), threads).with_nodes(nodes).with_fragments(fragments),
                    )
                    .unwrap_or_else(|e| {
                        panic!("seed {seed} ({nodes},{threads}) fragments={fragments}: {q}: {e}")
                    });
                    assert_eq!(
                        out, base,
                        "seed {seed} ({nodes},{threads}) fragments={fragments}: {q}"
                    );
                }
            }
        }
    }
}

/// The ISSUE 5 wire-bytes criterion: on a scan→filter→project→aggregate
/// query over ≥ 2 nodes, fragment dispatch ships each remote node's
/// input span exactly once — strictly fewer wire bytes than
/// operator-at-a-time dispatch — and reports the fused operator list.
#[test]
fn fragment_dispatch_ships_strictly_fewer_wire_bytes() {
    if chaos_env() {
        return;
    }
    let cat = catalog(30_000, 600, Some(1.2), 43);
    let q = "SELECT k2, COUNT(*) AS n, SUM(vv) AS s FROM \
             (SELECT k + 1 AS k2, v * 2.0 AS vv FROM facts WHERE v < 800.0) t GROUP BY k2";
    for (nodes, threads) in [(2usize, 4usize), (4, 2)] {
        let (frag_out, frag) = run_sql_with_stats(
            q,
            &ctx(cat.clone(), threads).with_nodes(nodes).with_fragments(true),
        )
        .unwrap();
        let (op_out, op) = run_sql_with_stats(
            q,
            &ctx(cat.clone(), threads).with_nodes(nodes).with_fragments(false),
        )
        .unwrap();
        assert_eq!(frag_out, op_out, "({nodes},{threads})");
        let (fw, ow) = (frag.total_wire_bytes(), op.total_wire_bytes());
        assert!(fw > 0, "({nodes},{threads}): fragment shipped nothing");
        assert!(
            fw < ow,
            "({nodes},{threads}): fragment wire bytes {fw} !< operator-at-a-time {ow}"
        );
        assert_eq!(frag.fragments.len(), 1, "{:?}", frag.fragments);
        let f = &frag.fragments[0];
        // Both shapes are multi-node, so the shuffled finalize engages
        // (and tags the breaker) by default.
        assert_eq!(f.ops, vec!["filter", "project", "aggregate", "shuffle"]);
        assert_eq!(f.wire_bytes, fw, "all shipping happened in the fragment");
        assert!(f.est_operator_wire_bytes > f.wire_bytes, "{f:?}");
        assert!(op.fragments.is_empty());
        let report = frag.report();
        assert!(report.contains("filter+project+aggregate"), "{report}");
    }
}

/// Fragments obey stealing-vs-static equivalence too: the scheduler
/// only moves where a morsel runs.
#[test]
fn fragment_static_matches_stealing() {
    let cat = catalog(30_000, 600, Some(1.2), 44);
    for q in FRAGMENT_QUERIES {
        let steal = run_sql(q, &ctx(cat.clone(), 4).with_nodes(2)).unwrap();
        let fixed = run_sql(q, &ctx(cat.clone(), 4).with_nodes(2).with_stealing(false))
            .unwrap_or_else(|e| panic!("static: {q}: {e}"));
        assert_eq!(fixed, steal, "static vs stealing: {q}");
    }
}

/// The ISSUE 10 acceptance matrix: the hash-partitioned shuffle
/// finalize (grouped aggregation folded on owning partitions,
/// tree-structured scalar and sorted-run merges, partitioned join
/// builds) must be byte-identical to the leader-merge baseline
/// (`SNOWPARK_SHUFFLE=0` / `with_shuffle(false)`) AND to the
/// sequential path at `(nodes, threads)` ∈
/// {(1,1), (1,8), (2,4), (4,2), (8,2)} — the widest shape exceeds the
/// morsel count, exercising the partition-count clamp — over uniform
/// and Zipf-1.2 keys.
#[test]
fn shuffle_matches_leader_merge_at_every_shape() {
    for (seed, zipf) in [(61u64, None), (62, Some(1.2))] {
        let cat = catalog(30_000, 600, zipf, seed);
        for q in FRAGMENT_QUERIES.iter().chain(QUERIES) {
            let base = run_sql(q, &ctx(cat.clone(), 1).with_nodes(1).with_shuffle(false))
                .unwrap_or_else(|e| panic!("seed {seed}: {q}: {e}"));
            for (nodes, threads) in [(1usize, 1usize), (1, 8), (2, 4), (4, 2), (8, 2)] {
                for shuffle in [true, false] {
                    let out = run_sql(
                        q,
                        &ctx(cat.clone(), threads).with_nodes(nodes).with_shuffle(shuffle),
                    )
                    .unwrap_or_else(|e| {
                        panic!("seed {seed} ({nodes},{threads}) shuffle={shuffle}: {q}: {e}")
                    });
                    assert_eq!(
                        out, base,
                        "seed {seed} ({nodes},{threads}) shuffle={shuffle}: {q}"
                    );
                }
            }
        }
    }
}

/// Shuffle + chaos: a killed partition owner's partitions reroute to
/// survivors without disturbing a single byte. With the shuffle pinned
/// on, permanently dead remotes (blacklist → reroute, degrading to the
/// leader), an injected panic, and a mixed ship/eval plan all leave
/// every query identical to the fault-free sequential run — and on the
/// permanent-death plan the recovery is visible in the retry and
/// blacklist counters.
#[test]
fn shuffle_reroutes_killed_partition_owners_byte_identically() {
    let cat = catalog(30_000, 600, Some(1.2), 63);
    for plan in ["seed=16;ship=1:99", "seed=17;panic=2:1", "seed=18;ship=1:99;eval=3:99"] {
        for q in FAULT_QUERIES {
            let base = run_sql(q, &ctx(cat.clone(), 1).with_nodes(1))
                .unwrap_or_else(|e| panic!("{q}: {e}"));
            for (nodes, threads) in [(2usize, 4usize), (4, 2), (8, 2)] {
                let c = fault_ctx(cat.clone(), threads, nodes, plan).with_shuffle(true);
                let (out, stats) = run_sql_with_stats(q, &c)
                    .unwrap_or_else(|e| panic!("({nodes},{threads}) {plan}: {q}: {e}"));
                assert_eq!(out, base, "({nodes},{threads}) {plan}: {q}");
                if plan == "seed=16;ship=1:99" {
                    assert!(
                        stats.total_retries() >= 2,
                        "({nodes},{threads}) {plan}: no retries recorded: {stats:?}"
                    );
                    assert!(
                        stats.total_blacklisted() >= 1,
                        "({nodes},{threads}) {plan}: owner never blacklisted: {stats:?}"
                    );
                }
            }
        }
    }
}

/// Static assignment (the PR 3 plan) and work stealing must agree
/// bit-for-bit at every shape — the scheduler only moves *where* a
/// morsel runs, never what it computes or how results merge.
#[test]
fn static_assignment_matches_stealing_randomized() {
    let cat = catalog(30_000, 600, Some(1.2), 21);
    for q in QUERIES {
        let steal = run_sql(q, &ctx(cat.clone(), 4).with_nodes(2)).unwrap();
        let fixed = run_sql(q, &ctx(cat.clone(), 4).with_nodes(2).with_stealing(false))
            .unwrap_or_else(|e| panic!("static: {q}: {e}"));
        assert_eq!(fixed, steal, "static vs stealing: {q}");
    }
}

/// Node dispatch is observable: per-node morsel counts and wire bytes
/// land in `QueryStats`, and the scheduler's stats framework can fold
/// them into its balance history.
#[test]
fn node_stats_feed_balance_history() {
    if chaos_env() {
        return;
    }
    let cat = catalog(30_000, 600, Some(1.2), 31);
    let q = "SELECT k, COUNT(*) AS n, SUM(v) AS s FROM facts GROUP BY k";
    let (_, stats) = run_sql_with_stats(q, &ctx(cat, 4).with_nodes(2)).unwrap();
    assert_eq!(stats.node_stats.len(), 2, "{stats:?}");
    assert!(stats.node_stats[1].wire_bytes > 0, "remote node shipped nothing");
    assert!(stats.per_node_morsels().iter().all(|&m| m > 0));
    assert!(stats.per_node_busy_ns().iter().all(|&b| b > 0));
    let framework = StatsFramework::new(8);
    framework.record_node_balance(q, &stats.per_node_busy_ns(), stats.total_steals());
    let h = framework.balance_lookback(q, 1);
    assert_eq!(h.len(), 1);
    assert!(h[0].skew >= 1.0);
}

/// Queries spanning the operator zoo (grouped/global aggregates with a
/// UDAF, joins, top-k sort, a fused fragment chain, a subquery) for the
/// fault-recovery differential matrix — smaller than QUERIES because
/// every entry runs under several plans at several shapes.
const FAULT_QUERIES: &[&str] = &[
    "SELECT k, COUNT(*) AS n, COUNT(v) AS nv, SUM(v) AS s, AVG(v) AS a, \
     MIN(v) AS lo, MAX(v) AS hi FROM facts GROUP BY k",
    "SELECT COUNT(*) AS n, SUM(v) AS s, sumsq(k) AS q FROM facts",
    "SELECT facts.k, label FROM facts LEFT JOIN dim ON facts.k = dim.k",
    "SELECT k, v FROM facts ORDER BY v DESC, k LIMIT 100",
    "SELECT k2, COUNT(*) AS n, SUM(vv) AS s FROM \
     (SELECT k + 1 AS k2, v * 2.0 AS vv FROM facts WHERE v < 800.0) t GROUP BY k2",
    "SELECT tag, n FROM (SELECT tag, COUNT(*) AS n FROM facts GROUP BY tag) t \
     WHERE n > 100",
];

/// Seeded fault plans covering every injection kind and recovery path:
/// transient ship failures (retry heals), mixed eval+ship counts,
/// an injected worker panic, probabilistic faults plus a slow node,
/// and permanently-dead remotes (blacklist → reroute → leader).
const FAULT_PLANS: &[&str] = &[
    "seed=7;ship=1:2",
    "seed=8;eval=1:1;ship=2:1",
    "seed=9;panic=1:1",
    "seed=10;ship=1:p0.5;eval=2:p0.3;slow=1:1",
    "seed=11;ship=1:99;ship=2:99;ship=3:99",
];

/// The fault-recovery acceptance matrix: for any seeded plan that
/// leaves at least one live node (node 0 is never injectable), every
/// query's output is byte-identical to the fault-free sequential run
/// at `(nodes, threads)` ∈ {(1,1), (1,8), (2,4), (4,2)}.
#[test]
fn fault_injection_preserves_output_at_every_shape() {
    let cat = catalog(30_000, 600, Some(1.2), 51);
    for q in FAULT_QUERIES {
        let base = run_sql(q, &ctx(cat.clone(), 1).with_nodes(1))
            .unwrap_or_else(|e| panic!("{q}: {e}"));
        for plan in FAULT_PLANS {
            for (nodes, threads) in [(1usize, 1usize), (1, 8), (2, 4), (4, 2)] {
                let out = run_sql(q, &fault_ctx(cat.clone(), threads, nodes, plan))
                    .unwrap_or_else(|e| panic!("({nodes},{threads}) {plan}: {q}: {e}"));
                assert_eq!(out, base, "({nodes},{threads}) {plan}: {q}");
            }
        }
    }
}

/// Recovery is observable: a node whose ship keeps failing accumulates
/// retry counters and a blacklist entry in `QueryStats`, and the
/// `--stats` report prints them.
#[test]
fn fault_recovery_records_retries_and_blacklists() {
    if chaos_env() {
        return;
    }
    let cat = catalog(30_000, 600, None, 52);
    let q = "SELECT k, COUNT(*) AS n, SUM(v) AS s FROM facts GROUP BY k";
    let (out, stats) =
        run_sql_with_stats(q, &fault_ctx(cat.clone(), 4, 2, "seed=12;ship=1:99")).unwrap();
    let base = run_sql(q, &ctx(cat, 1).with_nodes(1)).unwrap();
    assert_eq!(out, base);
    assert!(stats.total_retries() >= 2, "{stats:?}");
    assert_eq!(stats.total_blacklisted(), 1, "{stats:?}");
    assert!(stats.node_stats[1].retries >= 2, "{:?}", stats.node_stats);
    let report = stats.report();
    assert!(report.contains("retries"), "{report}");
}

/// The zero-overhead invariant the A12 ablation measures: with no
/// fault plan, the dispatch path takes no retry machinery with it —
/// the counters are exactly zero at every multi-node shape.
#[test]
fn retry_counters_zero_without_fault_plan() {
    if chaos_env() {
        return;
    }
    let cat = catalog(30_000, 600, None, 53);
    let q = "SELECT k, COUNT(*) AS n, SUM(v) AS s FROM facts GROUP BY k";
    for (nodes, threads) in [(2usize, 4usize), (4, 2)] {
        let (_, stats) =
            run_sql_with_stats(q, &ctx(cat.clone(), threads).with_nodes(nodes)).unwrap();
        assert_eq!(stats.total_retries(), 0, "({nodes},{threads}): {stats:?}");
        assert_eq!(stats.total_blacklisted(), 0, "({nodes},{threads}): {stats:?}");
    }
}

/// The CI chaos leg's own strict assertion: under the seeded
/// env-supplied plan (`ship=1:2`), recovery must actually have
/// happened — nonzero retry counters — while outputs stay identical
/// (the differential tests in this binary check that part).
#[test]
fn chaos_env_plan_records_retries() {
    if !chaos_env() {
        return;
    }
    let cat = catalog(30_000, 600, None, 54);
    let q = "SELECT k, COUNT(*) AS n, SUM(v) AS s FROM facts GROUP BY k";
    let (_, stats) = run_sql_with_stats(q, &ctx(cat, 4).with_nodes(2)).unwrap();
    assert!(stats.total_retries() > 0, "chaos plan injected no recoverable fault: {stats:?}");
}

/// When every remote node is dead, the statement degrades to
/// leader-only execution and still completes with the exact answer.
#[test]
fn all_remotes_blacklisted_degrades_to_leader() {
    let cat = catalog(30_000, 600, Some(1.2), 55);
    let q = "SELECT k, COUNT(*) AS n, SUM(v) AS s FROM facts GROUP BY k";
    let base = run_sql(q, &ctx(cat.clone(), 1).with_nodes(1)).unwrap();
    let (out, stats) =
        run_sql_with_stats(q, &fault_ctx(cat, 2, 4, "seed=13;ship=1:99;ship=2:99;ship=3:99"))
            .unwrap();
    assert_eq!(out, base);
    assert_eq!(stats.total_blacklisted(), 3, "{stats:?}");
    assert!(stats.total_retries() >= 3, "{stats:?}");
    assert!(stats.node_stats[0].morsels > 0, "leader ran the rerouted spans: {stats:?}");
}

/// A deadline-bound statement against a stalled node returns
/// `DeadlineExceeded` promptly — no hang, no leaked workers — and the
/// engine keeps working afterwards.
#[test]
fn deadline_bound_query_returns_deadline_exceeded_promptly() {
    let cat = catalog(30_000, 600, None, 56);
    let q = "SELECT k, COUNT(*) AS n, SUM(v) AS s FROM facts GROUP BY k";
    let c = fault_ctx(cat.clone(), 4, 2, "seed=14;slow=1:120000")
        .with_cancel(CancelToken::with_deadline(std::time::Duration::from_millis(250)));
    let started = std::time::Instant::now();
    let err = run_sql(q, &c).unwrap_err();
    assert!(is_deadline_exceeded(&err), "{err:#}");
    assert!(started.elapsed() < std::time::Duration::from_secs(20), "{:?}", started.elapsed());
    // The process is healthy afterwards: a fresh fault-free context
    // over the same catalog still answers.
    let base = run_sql(q, &ctx(cat.clone(), 1).with_nodes(1)).unwrap();
    let again = run_sql(q, &ctx(cat, 4).with_nodes(2)).unwrap();
    assert_eq!(again, base);
}

#[test]
fn parallel_matches_rowwise_reference() {
    // Transitively: parallel == sequential-vectorized == row-at-a-time
    // reference. Spot-check the first directly against the reference.
    let cat = catalog(20_000, 300, Some(1.1), 9);
    for q in [
        "SELECT k, COUNT(*) AS n, SUM(v) AS s FROM facts GROUP BY k",
        "SELECT facts.k, label FROM facts JOIN dim ON facts.k = dim.k",
        "SELECT k, v FROM facts ORDER BY v DESC, k LIMIT 50",
    ] {
        let reference =
            run_sql(q, &ctx(cat.clone(), 1).with_vectorized(false)).unwrap();
        let par = run_sql(q, &ctx(cat.clone(), 8)).unwrap();
        assert_eq!(par, reference, "{q}");
    }
}

#[test]
fn exchange_report_matches_simulation() {
    // The deterministic makespan model must assign batches exactly as
    // the real exchange does: pin batch and remote-batch counts to the
    // report, per mode, on a layout with empty and uneven partitions.
    let mut r = UdfRegistry::new();
    r.register_scalar("ident", DataType::Float64, Arc::new(|args| Ok(args[0].clone())));
    let reg = Arc::new(r);
    let pool_cfg = PoolConfig {
        nodes: 2,
        procs_per_node: 2,
        queue_depth: 2,
        transport: TransportCost::default(),
    };
    let pool = InterpreterPool::spawn(pool_cfg, reg.clone(), Arc::new(UdfStatsStore::new()));
    let sizes = [100usize, 5, 0, 37, 64];
    let parts: Vec<RowSet> = sizes
        .iter()
        .map(|&n| {
            RowSet::new(
                Schema::new(vec![Field::new("x", DataType::Float64)]),
                vec![Column::from_f64((0..n).map(|i| i as f64).collect())],
            )
            .unwrap()
        })
        .collect();
    for (mode, redistribute) in
        [(ExchangeMode::Local, false), (ExchangeMode::RoundRobin, true)]
    {
        let cfg = ExchangeConfig { mode, batch_rows: 16, threshold_ns: 0 };
        let (_, report) = run_udf_exchange(&parts, "ident", &pool, &reg, cfg).unwrap();
        let sim = simulate_exchange(
            &sizes,
            1_000,
            8,
            pool_cfg.nodes,
            pool_cfg.procs_per_node,
            pool_cfg.transport,
            cfg,
            redistribute,
        );
        assert_eq!(report.redistributed, redistribute, "{mode:?}");
        assert_eq!(report.batches, sim.total_batches, "{mode:?}");
        assert_eq!(report.remote_batches, sim.remote_batches, "{mode:?}");
    }
}
