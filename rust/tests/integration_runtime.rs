//! End-to-end validation of the AOT bridge: artifacts produced by
//! `python/compile/aot.py` (JAX + Pallas, interpret=True) are loaded,
//! compiled, and executed via the PJRT CPU client, and the numerics are
//! checked against independently-computed rust oracles.
//!
//! Requires `make artifacts` to have run; tests are skipped (not failed)
//! when the artifacts directory is missing so `cargo test` stays runnable
//! on a fresh checkout.

use snowpark::runtime::XlaRuntime;

fn runtime() -> Option<XlaRuntime> {
    let dir = XlaRuntime::default_dir();
    if !XlaRuntime::available(&dir) {
        eprintln!("skipping: no artifacts at {}", dir.display());
        return None;
    }
    Some(XlaRuntime::open(dir).expect("open runtime"))
}

/// Deterministic pseudo-random f32s (SplitMix64-derived), so the test is
/// reproducible without a rand crate.
fn pseudo_data(n: usize, seed: u64) -> Vec<f32> {
    let mut state = seed;
    (0..n)
        .map(|_| {
            state = state.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = state;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^= z >> 31;
            // Map to [-50, 50).
            (z >> 40) as f32 / (1u64 << 24) as f32 * 100.0 - 50.0
        })
        .collect()
}

const B: usize = 2048;
const F: usize = 16;
const C: usize = 32;

#[test]
fn manifest_lists_all_kernels() {
    let Some(rt) = runtime() else { return };
    let names = rt.kernel_names();
    for want in [
        "minmax_stats",
        "minmax_apply",
        "one_hot",
        "pearson_moments",
        "featurize",
    ] {
        assert!(names.iter().any(|n| n == want), "missing kernel {want}");
    }
    let spec = rt.spec("minmax_stats").unwrap();
    assert_eq!(spec.inputs[0].dims, vec![B, F]);
    assert_eq!(spec.outputs[0].dims, vec![2, F]);
}

#[test]
fn minmax_stats_and_apply_match_rust_oracle() {
    let Some(rt) = runtime() else { return };
    let x = pseudo_data(B * F, 7);

    // Oracle: column-wise min/max.
    let mut lo = vec![f32::INFINITY; F];
    let mut hi = vec![f32::NEG_INFINITY; F];
    for r in 0..B {
        for c in 0..F {
            let v = x[r * F + c];
            lo[c] = lo[c].min(v);
            hi[c] = hi[c].max(v);
        }
    }

    let stats_kernel = rt.load("minmax_stats").unwrap();
    let out = stats_kernel.execute_f32(&[x.clone()]).unwrap();
    assert_eq!(out.len(), 1);
    let stats = &out[0];
    assert_eq!(stats.len(), 2 * F);
    for c in 0..F {
        assert_eq!(stats[c], lo[c], "min col {c}");
        assert_eq!(stats[F + c], hi[c], "max col {c}");
    }

    let apply_kernel = rt.load("minmax_apply").unwrap();
    let scaled = &apply_kernel.execute_f32(&[x.clone(), stats.clone()]).unwrap()[0];
    for r in 0..B {
        for c in 0..F {
            let rng = hi[c] - lo[c];
            let want = if rng == 0.0 { 0.0 } else { (x[r * F + c] - lo[c]) / rng };
            let got = scaled[r * F + c];
            assert!(
                (got - want).abs() <= 1e-6,
                "r={r} c={c} got={got} want={want}"
            );
        }
    }
}

#[test]
fn one_hot_matches_rust_oracle() {
    let Some(rt) = runtime() else { return };
    let codes: Vec<f32> = (0..B).map(|i| ((i * 7) % C) as f32).collect();
    let kernel = rt.load("one_hot").unwrap();
    let y = &kernel.execute_f32(&[codes.clone()]).unwrap()[0];
    assert_eq!(y.len(), B * C);
    for r in 0..B {
        for c in 0..C {
            let want = if codes[r] as usize == c { 1.0 } else { 0.0 };
            assert_eq!(y[r * C + c], want, "r={r} c={c}");
        }
    }
}

#[test]
fn pearson_moments_match_rust_oracle() {
    let Some(rt) = runtime() else { return };
    let x = pseudo_data(B * F, 11);
    let kernel = rt.load("pearson_moments").unwrap();
    let out = kernel.execute_f32(&[x.clone()]).unwrap();
    assert_eq!(out.len(), 2);
    let (xtx, colsum) = (&out[0], &out[1]);

    // Oracle in f64 then compare loosely (kernel accumulates in f32).
    let mut want_xtx = vec![0f64; F * F];
    let mut want_sum = vec![0f64; F];
    for r in 0..B {
        for a in 0..F {
            want_sum[a] += x[r * F + a] as f64;
            for b in 0..F {
                want_xtx[a * F + b] += (x[r * F + a] as f64) * (x[r * F + b] as f64);
            }
        }
    }
    for i in 0..F * F {
        let got = xtx[i] as f64;
        assert!(
            (got - want_xtx[i]).abs() <= want_xtx[i].abs() * 1e-4 + 1e-1,
            "xtx[{i}] got={got} want={}",
            want_xtx[i]
        );
    }
    for c in 0..F {
        let got = colsum[c] as f64;
        assert!(
            (got - want_sum[c]).abs() <= want_sum[c].abs() * 1e-4 + 1e-1,
            "colsum[{c}] got={got} want={}",
            want_sum[c]
        );
    }
}

#[test]
fn featurize_concats_scaled_and_one_hot() {
    let Some(rt) = runtime() else { return };
    let x = pseudo_data(B * F, 13);
    let codes: Vec<f32> = (0..B).map(|i| ((i * 3) % C) as f32).collect();

    let stats_kernel = rt.load("minmax_stats").unwrap();
    let stats = stats_kernel.execute_f32(&[x.clone()]).unwrap()[0].clone();

    let fused = rt.load("featurize").unwrap();
    let feats = &fused
        .execute_f32(&[x.clone(), codes.clone(), stats.clone()])
        .unwrap()[0];
    assert_eq!(feats.len(), B * (F + C));

    let apply_kernel = rt.load("minmax_apply").unwrap();
    let scaled = &apply_kernel.execute_f32(&[x.clone(), stats]).unwrap()[0];
    let onehot_kernel = rt.load("one_hot").unwrap();
    let encoded = &onehot_kernel.execute_f32(&[codes]).unwrap()[0];

    for r in 0..B {
        for c in 0..F {
            assert_eq!(feats[r * (F + C) + c], scaled[r * F + c], "num r={r} c={c}");
        }
        for c in 0..C {
            assert_eq!(
                feats[r * (F + C) + F + c],
                encoded[r * C + c],
                "cat r={r} c={c}"
            );
        }
    }
}

#[test]
fn executable_cache_compiles_once() {
    let Some(rt) = runtime() else { return };
    assert_eq!(rt.compiled_count(), 0);
    let a = rt.load("one_hot").unwrap();
    let b = rt.load("one_hot").unwrap();
    assert_eq!(rt.compiled_count(), 1);
    // Both handles execute fine.
    let codes: Vec<f32> = vec![1.0; B];
    a.execute_f32(&[codes.clone()]).unwrap();
    b.execute_f32(&[codes]).unwrap();
}

#[test]
fn execute_rejects_wrong_arity_and_shape() {
    let Some(rt) = runtime() else { return };
    let k = rt.load("minmax_apply").unwrap();
    assert!(k.execute_f32(&[vec![0.0; B * F]]).is_err(), "arity");
    assert!(
        k.execute_f32(&[vec![0.0; 3], vec![0.0; 2 * F]]).is_err(),
        "shape"
    );
}
