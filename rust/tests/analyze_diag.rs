//! Golden corpus for the plan-time semantic analyzer.
//!
//! Three layers of pinning:
//! 1. broken statements keep their stable diagnostic **codes and
//!    operator paths** (the codes are API — docs and clients match on
//!    them);
//! 2. the analyzer **accepts everything the repo actually runs**: the
//!    serving workload catalog, a statement per TPCx-BB UDF, and the
//!    integration SQL suite's statements (a false reject here would
//!    brick the serving layer's pre-admission gate);
//! 3. a seeded fuzz feeds random plan/expression trees straight into
//!    [`analyze_plan`] — analysis must never panic, whatever the shape.

use snowpark::engine::{analyze_plan, analyze_sql, AggCall, AggFunc, Catalog, Plan};
use snowpark::session::Session;
use snowpark::sim::{register_udfs, TpcxBbDataset, SERVING_CATALOG, TPCXBB_QUERIES};
use snowpark::sql::{BinaryOp, Expr, JoinKind, OrderKey, UnaryOp};
use snowpark::types::{Column, DataType, Field, RowSet, Schema, Value};
use snowpark::udf::UdfRegistry;
use snowpark::util::rng::Rng;

/// Two small tables with every engine type, plus a colliding column
/// name (`a`) for ambiguity cases.
fn demo_catalog() -> Catalog {
    let cat = Catalog::new();
    cat.register(
        "t",
        RowSet::new(
            Schema::new(vec![
                Field::new("a", DataType::Int64),
                Field::new("b", DataType::Float64),
                Field::new("s", DataType::Utf8),
                Field::new("c", DataType::Bool),
            ]),
            vec![
                Column::from_i64(vec![1, 2, 3]),
                Column::from_f64(vec![1.5, 2.5, 3.5]),
                Column::from_strings(vec!["x".into(), "y".into(), "z".into()]),
                Column::from_bools(vec![true, false, true]),
            ],
        )
        .unwrap(),
    );
    cat.register(
        "u",
        RowSet::new(
            Schema::new(vec![
                Field::new("a", DataType::Int64),
                Field::new("x", DataType::Int64),
            ]),
            vec![Column::from_i64(vec![1, 2]), Column::from_i64(vec![10, 20])],
        )
        .unwrap(),
    );
    cat
}

#[test]
fn golden_corpus_codes_and_paths_are_stable() {
    let cat = demo_catalog();
    let udfs = UdfRegistry::new();
    // (sql, expected code, expected operator path of the first error).
    let corpus: &[(&str, &str, &str)] = &[
        ("SELEC nope FROM t", "E000", "(parse)"),
        ("SELECT a FROM t WHERE sum(a) > 1", "E010", "(plan)"),
        ("SELECT nope FROM t WHERE a > 1", "E001", "Scan(t) → Filter → Project"),
        ("SELECT a FROM t WHERE nope > 1", "E001", "Scan(t) → Filter"),
        (
            "SELECT t.a FROM t JOIN u ON t.a = u.a WHERE a > 1",
            "E002",
            "Scan(t) → Join(u) → Filter",
        ),
        ("SELECT * FROM missing", "E003", "Scan(missing)"),
        ("SELECT wat(a) AS w FROM t", "E004", "Scan(t) → Project"),
        ("SELECT a + s AS v FROM t", "E101", "Scan(t) → Project"),
        ("SELECT a FROM t WHERE a = s", "E102", "Scan(t) → Filter"),
        ("SELECT a FROM t WHERE (a > 1) AND s", "E103", "Scan(t) → Filter"),
        ("SELECT NOT s AS v FROM t", "E104", "Scan(t) → Project"),
        ("SELECT -s AS v FROM t", "E105", "Scan(t) → Project"),
        ("SELECT a FROM t WHERE a BETWEEN 1 AND 'z'", "E106", "Scan(t) → Filter"),
        ("SELECT substr(s) AS v FROM t", "E110", "Scan(t) → Project"),
        ("SELECT upper(a) AS v FROM t", "E111", "Scan(t) → Project"),
        ("SELECT sum(s) AS v FROM t", "E120", "Scan(t) → Aggregate"),
        ("SELECT count() AS v FROM t", "E121", "Scan(t) → Aggregate"),
        ("SELECT a FROM t WHERE a + 1", "E130", "Scan(t) → Filter"),
    ];
    for (sql, code, path) in corpus {
        let a = analyze_sql(sql, &cat, &udfs);
        let errs: Vec<_> = a.errors().collect();
        assert!(
            !errs.is_empty(),
            "{sql}: expected a {code} rejection, analysis accepted\n{}",
            a.render()
        );
        assert_eq!(errs[0].code.as_str(), *code, "{sql}: got {}", errs[0]);
        assert_eq!(errs[0].path, *path, "{sql}: got {}", errs[0]);
    }
}

#[test]
fn lints_warn_with_stable_codes_but_accept() {
    let cat = demo_catalog();
    let udfs = UdfRegistry::new();
    let corpus: &[(&str, &str)] = &[
        ("SELECT a FROM t WHERE true", "W001"),
        ("SELECT a FROM t WHERE false", "W002"),
        ("SELECT a FROM t WHERE b = NULL", "W003"),
        ("SELECT a FROM (SELECT a, b FROM t) q", "W004"),
        ("SELECT a FROM t WHERE s IN (1, 2)", "W005"),
        ("SELECT CASE WHEN a THEN 1 ELSE 2 END AS v FROM t", "W006"),
        ("SELECT t.a FROM t JOIN u ON t.s = u.x", "W007"),
        ("SELECT CASE WHEN c THEN 1 ELSE 'x' END AS v FROM t", "W008"),
    ];
    for (sql, code) in corpus {
        let a = analyze_sql(sql, &cat, &udfs);
        assert!(a.is_ok(), "{sql}: lints must not reject\n{}", a.render_errors());
        assert!(
            a.diagnostics.iter().any(|d| d.code.as_str() == *code),
            "{sql}: expected {code}, got {:?}",
            a.diagnostics.iter().map(|d| d.code.as_str()).collect::<Vec<_>>()
        );
    }
}

// ------------------------------------------------------ corpus acceptance

#[test]
fn serving_catalog_and_udf_statements_all_analyze_clean() {
    // Exactly the serving layer's world: the merged TPCx-BB catalog and
    // the sim UDF registry. Every catalog statement must pass — the
    // server rejects failures before admission, so a false positive
    // here means the serving workload cannot run at all.
    let catalog = Catalog::new();
    TpcxBbDataset::generate(500, 4, 1.4, 7).register_merged(&catalog).unwrap();
    let mut udfs = UdfRegistry::new();
    register_udfs(&mut udfs);
    for stmt in SERVING_CATALOG {
        let a = analyze_sql(stmt.sql, &catalog, &udfs);
        assert!(a.is_ok(), "{}: {}", stmt.name, a.render_errors());
        assert!(!a.schema.is_empty(), "{}: no output schema inferred", stmt.name);
        assert!(a.cold_bytes_hint() >= 1, "{}", stmt.name);
    }
    // One scalar-UDF statement per TPCx-BB query.
    for q in TPCXBB_QUERIES {
        let sql =
            format!("SELECT {}({}) AS v FROM {}", q.udf, q.input_cols.join(", "), q.table);
        let a = analyze_sql(&sql, &catalog, &udfs);
        assert!(a.is_ok(), "{}: {}", q.name, a.render_errors());
    }
}

#[test]
fn integration_sql_suite_statements_all_analyze_clean() {
    // The statements the integration suite executes, checked through
    // the session front door (`Session::check_sql`) over the same
    // dataset shape the suite registers.
    let s = Session::builder().build().unwrap();
    TpcxBbDataset::generate(1_000, 2, 1.2, 11).register(&s).unwrap();
    let suite = [
        "SELECT COUNT(*) AS n FROM store_sales",
        "SELECT SUM(quantity) AS q, MIN(price) AS lo, MAX(price) AS hi FROM store_sales",
        "SELECT category, COUNT(*) AS n, SUM(price * quantity) AS rev \
         FROM store_sales JOIN items ON store_sales.item_id = items.item_id \
         GROUP BY category HAVING COUNT(*) > 5 ORDER BY rev DESC LIMIT 4",
        "SELECT band, COUNT(*) AS n FROM \
         (SELECT CASE WHEN stars >= 4 THEN 'good' WHEN stars >= 2 THEN 'mid' \
          ELSE 'bad' END AS band FROM product_reviews) t \
         GROUP BY band ORDER BY band",
        "SELECT upper(category) AS cat FROM items \
         WHERE category IN ('toys', 'books') AND item_id BETWEEN 0 AND 100 LIMIT 5",
    ];
    for sql in suite {
        let a = s.check_sql(sql);
        assert!(a.is_ok(), "{sql}: {}", a.render_errors());
    }
}

// ------------------------------------------------------------- AST fuzz

fn rand_expr(rng: &mut Rng, depth: usize) -> Expr {
    if depth == 0 || rng.below(3) == 0 {
        return match rng.below(6) {
            0 => Expr::Literal(Value::Int(rng.below(100) as i64)),
            1 => Expr::Literal(Value::Float(rng.f64())),
            2 => Expr::Literal(Value::Str("s".into())),
            3 => Expr::Literal(Value::Null),
            4 => Expr::Literal(Value::Bool(rng.below(2) == 0)),
            _ => {
                let names = ["a", "b", "s", "c", "t.a", "nope", "x", "__dummy"];
                Expr::Column(names[rng.below(names.len() as u64) as usize].to_string())
            }
        };
    }
    let d = depth - 1;
    match rng.below(8) {
        0 => Expr::Unary {
            op: if rng.below(2) == 0 { UnaryOp::Neg } else { UnaryOp::Not },
            expr: Box::new(rand_expr(rng, d)),
        },
        1 => {
            let ops = [
                BinaryOp::Add,
                BinaryOp::Sub,
                BinaryOp::Mul,
                BinaryOp::Div,
                BinaryOp::Mod,
                BinaryOp::Eq,
                BinaryOp::NotEq,
                BinaryOp::Lt,
                BinaryOp::LtEq,
                BinaryOp::Gt,
                BinaryOp::GtEq,
                BinaryOp::And,
                BinaryOp::Or,
                BinaryOp::Concat,
            ];
            Expr::Binary {
                op: ops[rng.below(ops.len() as u64) as usize],
                left: Box::new(rand_expr(rng, d)),
                right: Box::new(rand_expr(rng, d)),
            }
        }
        2 => {
            let names =
                ["abs", "sqrt", "round", "substr", "upper", "length", "coalesce", "wat", "sum"];
            let n_args = rng.below(4) as usize;
            Expr::Func {
                name: names[rng.below(names.len() as u64) as usize].to_string(),
                args: (0..n_args).map(|_| rand_expr(rng, d)).collect(),
            }
        }
        3 => Expr::IsNull { expr: Box::new(rand_expr(rng, d)), negated: rng.below(2) == 0 },
        4 => Expr::InList {
            expr: Box::new(rand_expr(rng, d)),
            list: (0..rng.below(3) as usize + 1).map(|_| rand_expr(rng, d)).collect(),
            negated: rng.below(2) == 0,
        },
        5 => Expr::Between {
            expr: Box::new(rand_expr(rng, d)),
            low: Box::new(rand_expr(rng, d)),
            high: Box::new(rand_expr(rng, d)),
            negated: rng.below(2) == 0,
        },
        6 => Expr::Case {
            branches: (0..rng.below(2) as usize + 1)
                .map(|_| (rand_expr(rng, d), rand_expr(rng, d)))
                .collect(),
            else_value: if rng.below(2) == 0 {
                Some(Box::new(rand_expr(rng, d)))
            } else {
                None
            },
        },
        _ => Expr::Star,
    }
}

fn rand_plan(rng: &mut Rng, depth: usize) -> Plan {
    if depth == 0 || rng.below(4) == 0 {
        return match rng.below(3) {
            0 => Plan::Scan { table: "t".to_string(), alias: None },
            1 => Plan::Scan {
                table: "missing".to_string(),
                alias: Some("m".to_string()),
            },
            _ => Plan::TableFunc {
                name: if rng.below(2) == 0 { "__dual".to_string() } else { "gen".to_string() },
                args: vec![rand_expr(rng, 1)],
                alias: None,
            },
        };
    }
    let d = depth - 1;
    match rng.below(6) {
        0 => Plan::Filter {
            input: Box::new(rand_plan(rng, d)),
            predicate: rand_expr(rng, 2),
        },
        1 => Plan::Project {
            input: Box::new(rand_plan(rng, d)),
            exprs: (0..rng.below(3) as usize + 1)
                .map(|i| (rand_expr(rng, 2), format!("o{i}")))
                .collect(),
        },
        2 => {
            let funcs = [
                AggFunc::Count,
                AggFunc::CountStar,
                AggFunc::Sum,
                AggFunc::Avg,
                AggFunc::Min,
                AggFunc::Max,
                AggFunc::Udaf,
            ];
            let func = funcs[rng.below(funcs.len() as u64) as usize];
            let args = if func == AggFunc::CountStar || rng.below(4) == 0 {
                Vec::new()
            } else {
                vec![rand_expr(rng, 2)]
            };
            Plan::Aggregate {
                input: Box::new(rand_plan(rng, d)),
                group: if rng.below(2) == 0 {
                    vec![(rand_expr(rng, 1), "g".to_string())]
                } else {
                    Vec::new()
                },
                aggs: vec![AggCall {
                    func,
                    name: "agg".to_string(),
                    args,
                    out_name: "v".to_string(),
                }],
            }
        }
        3 => Plan::Join {
            left: Box::new(rand_plan(rng, d)),
            right: Box::new(rand_plan(rng, d)),
            kind: if rng.below(2) == 0 { JoinKind::Inner } else { JoinKind::Left },
            equi: if rng.below(2) == 0 {
                vec![(rand_expr(rng, 1), rand_expr(rng, 1))]
            } else {
                Vec::new()
            },
            residual: if rng.below(2) == 0 { Some(rand_expr(rng, 2)) } else { None },
        },
        4 => Plan::Sort {
            input: Box::new(rand_plan(rng, d)),
            keys: vec![OrderKey { expr: rand_expr(rng, 2), descending: rng.below(2) == 0 }],
        },
        _ => Plan::Limit {
            input: Box::new(rand_plan(rng, d)),
            n: rng.below(10) as usize,
        },
    }
}

#[test]
fn analysis_never_panics_on_random_plan_trees() {
    let cat = demo_catalog();
    let udfs = UdfRegistry::new();
    let mut rng = Rng::new(0xA1A1);
    for case in 0..600u64 {
        let mut r = rng.fork(case);
        let plan = rand_plan(&mut r, 4);
        // Whatever tree comes out — unknown tables, aggregates over
        // Star, UDAFs with no registration, nonsense predicates — the
        // analyzer must return diagnostics, never panic.
        let a = analyze_plan(&plan, &cat, &udfs);
        let _ = a.render();
        let _ = a.cold_bytes_hint();
    }
}
