//! Integration: §IV.A caching semantics under realistic event sequences —
//! shared binaries across combos, eviction under pressure, recycle, and
//! the solver cache's account-global sharing.

use std::sync::Arc;

use snowpark::packages::{
    EnvLookup, EnvironmentCache, PackageSpec, PackageUniverse, Prefetcher, Solver, SolverCache,
};

#[test]
fn overlapping_combos_share_binaries() {
    let u = PackageUniverse::generate(300, 41);
    let solver = Solver::new(&u);
    let numpy = u.by_name("numpy").unwrap();
    let pandas = u.by_name("pandas").unwrap();
    let sklearn = u.by_name("scikit-learn").unwrap();

    let r1 = solver.solve(&[PackageSpec::any(numpy), PackageSpec::any(pandas)]).unwrap();
    let r2 = solver.solve(&[PackageSpec::any(numpy), PackageSpec::any(sklearn)]).unwrap();

    let mut cache = EnvironmentCache::new(64 << 30);
    // Install combo 1 fully.
    if let EnvLookup::Partial { missing, .. } = cache.lookup(&r1) {
        for (p, v) in missing {
            let bytes = u.version(p, v).bytes;
            cache.install_binary(p, v, bytes);
        }
    }
    cache.register_env(&r1);
    assert_eq!(cache.lookup(&r1), EnvLookup::EnvHit);

    // Combo 2 shares the numpy-rooted closure: fewer missing than total.
    match cache.lookup(&r2) {
        EnvLookup::Partial { cached, missing } => {
            assert!(!cached.is_empty(), "shared binaries should be cached");
            assert!(missing.len() < r2.packages.len());
        }
        EnvLookup::EnvHit => panic!("combo 2 was never registered"),
    }
}

#[test]
fn eviction_pressure_preserves_correctness() {
    let u = PackageUniverse::generate(300, 43);
    let solver = Solver::new(&u);
    // Tiny cache: constant eviction churn.
    let mut cache = EnvironmentCache::new(32 << 20);
    let mut rng = snowpark::util::rng::Rng::new(7);
    for _ in 0..200 {
        let specs = u.sample_spec_set(&mut rng, 4);
        let Ok(r) = solver.solve(&specs) else { continue };
        match cache.lookup(&r) {
            EnvLookup::EnvHit => {}
            EnvLookup::Partial { missing, .. } => {
                for (p, v) in missing {
                    cache.install_binary(p, v, u.version(p, v).bytes);
                }
                cache.register_env(&r);
            }
        }
        // Core invariant under churn: never exceed capacity.
        assert!(cache.binary_bytes() <= cache.capacity_bytes());
    }
}

#[test]
fn solver_cache_key_is_account_agnostic() {
    // "global across all customer accounts": two 'tenants' with the same
    // spec set share one entry.
    let u = PackageUniverse::generate(200, 47);
    let solver = Solver::new(&u);
    let cache = Arc::new(SolverCache::new());
    let tenant_a_specs = vec![PackageSpec::any(0), PackageSpec::any(3)];
    let tenant_b_specs = vec![PackageSpec::any(3), PackageSpec::any(0)]; // reordered
    cache.resolve(&solver, &tenant_a_specs).unwrap();
    let (_, hit) = cache.resolve(&solver, &tenant_b_specs).unwrap();
    assert!(hit);
    assert_eq!(cache.len(), 1);
}

#[test]
fn prefetch_then_first_query_fast_path() {
    let u = PackageUniverse::generate(300, 53);
    let solver = Solver::new(&u);
    let mut cold = EnvironmentCache::new(64 << 30);
    let mut warm = EnvironmentCache::new(64 << 30);
    Prefetcher::new(32, 16 << 30).warm(&u, &mut warm);

    let r = solver
        .solve(&[PackageSpec::any(u.by_name("numpy").unwrap())])
        .unwrap();
    let missing = |c: &mut EnvironmentCache| match c.lookup(&r) {
        EnvLookup::Partial { missing, .. } => missing.len(),
        EnvLookup::EnvHit => 0,
    };
    assert!(missing(&mut warm) < missing(&mut cold));
}
