//! Integration: SQL engine end-to-end over the TPCx-BB-like dataset.

use std::sync::Arc;

use snowpark::session::Session;
use snowpark::sim::TpcxBbDataset;
use snowpark::types::{DataType, Value};

fn session() -> Arc<Session> {
    let s = Session::builder().build().unwrap();
    TpcxBbDataset::generate(2_000, 2, 1.2, 11).register(&s).unwrap();
    s
}

#[test]
fn counts_and_aggregates() {
    let s = session();
    let total = s.sql("SELECT COUNT(*) AS n FROM store_sales").unwrap();
    let n = total.row(0)[0].as_i64().unwrap();
    assert!(n >= 2_000, "{n}");
    let agg = s
        .sql("SELECT SUM(quantity) AS q, MIN(price) AS lo, MAX(price) AS hi FROM store_sales")
        .unwrap();
    assert!(agg.row(0)[0].as_i64().unwrap() > n);
    assert!(agg.row(0)[1].as_f64().unwrap() <= agg.row(0)[2].as_f64().unwrap());
}

#[test]
fn join_group_order_limit_pipeline() {
    let s = session();
    let rs = s
        .sql(
            "SELECT category, COUNT(*) AS n, SUM(price * quantity) AS rev \
             FROM store_sales JOIN items ON store_sales.item_id = items.item_id \
             GROUP BY category HAVING COUNT(*) > 5 ORDER BY rev DESC LIMIT 4",
        )
        .unwrap();
    assert!(rs.num_rows() >= 1 && rs.num_rows() <= 4);
    // Descending revenue.
    for i in 1..rs.num_rows() {
        let prev = rs.row(i - 1)[2].as_f64().unwrap();
        let cur = rs.row(i)[2].as_f64().unwrap();
        assert!(prev >= cur);
    }
}

#[test]
fn subqueries_and_case() {
    let s = session();
    let rs = s
        .sql(
            "SELECT band, COUNT(*) AS n FROM \
             (SELECT CASE WHEN stars >= 4 THEN 'good' WHEN stars >= 2 THEN 'mid' \
              ELSE 'bad' END AS band FROM product_reviews) t \
             GROUP BY band ORDER BY band",
        )
        .unwrap();
    assert!(rs.num_rows() >= 2);
    let total: i64 = (0..rs.num_rows())
        .map(|i| rs.row(i)[1].as_i64().unwrap())
        .sum();
    let reviews = s
        .sql("SELECT COUNT(*) AS n FROM product_reviews")
        .unwrap()
        .row(0)[0]
        .as_i64()
        .unwrap();
    assert_eq!(total, reviews);
}

#[test]
fn string_functions_and_predicates() {
    let s = session();
    let rs = s
        .sql(
            "SELECT upper(category) AS cat FROM items \
             WHERE category IN ('toys', 'books') AND item_id BETWEEN 0 AND 100 LIMIT 5",
        )
        .unwrap();
    for i in 0..rs.num_rows() {
        let v = rs.row(i)[0].as_str().unwrap().to_string();
        assert!(v == "TOYS" || v == "BOOKS");
    }
}

#[test]
fn scalar_udf_and_udaf_mix() {
    let s = session();
    s.register_scalar_udf(
        "clamp99",
        DataType::Float64,
        Arc::new(|args: &[Value]| {
            Ok(Value::Float(args[0].as_f64().unwrap_or(0.0).min(99.0)))
        }),
    );
    let rs = s
        .sql("SELECT AVG(clamp99(price)) AS a, MAX(clamp99(price)) AS m FROM store_sales")
        .unwrap();
    assert!(rs.row(0)[1].as_f64().unwrap() <= 99.0);
    assert!(rs.row(0)[0].as_f64().unwrap() <= 99.0);
}

#[test]
fn udtf_in_from_clause() {
    // §III.A: "UDTFs return a set of rows (i.e. a table)" — invoked via
    // TABLE(fn(args)) in FROM.
    use snowpark::types::{Column, Field, RowSet, Schema};
    let s = session();
    let schema = Schema::new(vec![
        Field::new("n", DataType::Int64),
        Field::new("sq", DataType::Int64),
    ]);
    let schema2 = schema.clone();
    let mut reg = s.udfs();
    reg.register_udtf(
        "squares",
        schema,
        Arc::new(move |args: &[Value]| {
            let k = args[0].as_i64().unwrap_or(0);
            RowSet::new(
                schema2.clone(),
                vec![
                    Column::from_i64((0..k).collect()),
                    Column::from_i64((0..k).map(|v| v * v).collect()),
                ],
            )
        }),
    );
    let ctx = snowpark::engine::ExecContext::new(
        std::sync::Arc::new(snowpark::engine::Catalog::new()),
        Arc::new(reg),
    );
    let rs = snowpark::engine::run_sql(
        "SELECT sq FROM TABLE(squares(5)) t WHERE n >= 2 ORDER BY sq DESC",
        &ctx,
    )
    .unwrap();
    assert_eq!(rs.num_rows(), 3);
    assert_eq!(rs.row(0)[0], Value::Int(16));
    assert_eq!(rs.row(2)[0], Value::Int(4));
}

#[test]
fn errors_are_reported_not_panics() {
    let s = session();
    assert!(s.sql("SELECT missing_col FROM store_sales").is_err());
    assert!(s.sql("SELECT * FROM no_such_table").is_err());
    assert!(s.sql("SELECT nope(price) FROM store_sales").is_err());
    assert!(s.sql("THIS IS NOT SQL").is_err());
    assert!(s.sql("SELECT SUM(AVG(price)) FROM store_sales").is_err());
}
