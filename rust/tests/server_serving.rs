//! End-to-end serving tests: concurrent results are byte-identical to
//! serial in-process execution, the server sustains 100+ concurrent
//! in-flight statements across tenants with zero lost work, and the load
//! harness is deterministic — same seed, same schedule, same ledger.

use std::sync::{Arc, Barrier};
use std::time::Duration;

use snowpark::engine::Catalog;
use snowpark::scheduler::{AdmissionConfig, AdmissionPolicy};
use snowpark::server::{ServeClient, ServeReply, Server, ServerConfig, TenantSnapshot};
use snowpark::session::Session;
use snowpark::sim::{run_load, Arrival, LoadConfig, TpcxBbDataset, SERVING_CATALOG};
use snowpark::types::WireBatch;
use snowpark::util::rng::Rng;

/// Shared retail catalog: seeded, so every call builds identical data.
fn retail_catalog(rows_per_table: usize, seed: u64) -> Arc<Catalog> {
    let catalog = Arc::new(Catalog::new());
    TpcxBbDataset::generate(rows_per_table, 4, 1.4, seed)
        .register_merged(&catalog)
        .unwrap();
    catalog
}

fn start_server(catalog: Arc<Catalog>, admission: AdmissionConfig) -> Server {
    Server::start(
        ServerConfig { admission, ..ServerConfig::default() },
        Box::new(move |_tenant| {
            Session::builder().shared_catalog(Arc::clone(&catalog)).build().map(Arc::new)
        }),
    )
    .unwrap()
}

/// The same statement must produce byte-identical results whether run
/// serially through an in-process [`Session`] or concurrently through the
/// server — admission control and the wire codec may reorder and queue
/// work, but never change answers.
#[test]
fn concurrent_serving_matches_serial_execution_byte_for_byte() {
    let catalog = retail_catalog(2_000, 9);

    // Serial reference: one plain session over the same shared catalog.
    let serial = Session::builder().shared_catalog(Arc::clone(&catalog)).build().unwrap();
    let expected: Vec<Vec<u8>> = SERVING_CATALOG
        .iter()
        .map(|stmt| {
            let rows = serial.sql(stmt.sql).unwrap();
            WireBatch::encode(&rows).as_bytes().to_vec()
        })
        .collect();

    // Concurrent: 8 clients across 2 tenants, each running the whole
    // catalog in its own shuffled order through a contended gate.
    let server = start_server(
        Arc::clone(&catalog),
        AdmissionConfig {
            slots: 2,
            capacity_bytes: 4 << 20,
            policy: AdmissionPolicy::Backfill,
        },
    );
    let addr = server.addr();
    let handles: Vec<_> = (0..8)
        .map(|c| {
            let expected = expected.clone();
            std::thread::spawn(move || {
                let tenant = format!("tenant-{}", c % 2);
                let mut client = ServeClient::connect(addr, &tenant).unwrap();
                client.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
                let mut order: Vec<usize> = (0..SERVING_CATALOG.len()).collect();
                Rng::new(100 + c as u64).shuffle(&mut order);
                for idx in order {
                    let stmt = &SERVING_CATALOG[idx];
                    match client.query(stmt.sql, 0).unwrap() {
                        ServeReply::Rows { rows, .. } => {
                            let got = WireBatch::encode(&rows).as_bytes().to_vec();
                            assert_eq!(
                                got, expected[idx],
                                "client {c}: served bytes for {} diverge from serial",
                                stmt.name
                            );
                        }
                        other => panic!("client {c}: {} denied: {other:?}", stmt.name),
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("differential client panicked");
    }

    let snap = server.shutdown();
    assert_eq!(snap.completed, 8 * SERVING_CATALOG.len() as u64);
    assert_eq!(snap.lost(), 0);
    assert_eq!(snap.worker_panics, 0);
}

/// Acceptance floor from the issue: ≥ 100 concurrent in-flight
/// statements across ≥ 2 tenants with zero lost work. A one-slot FIFO
/// gate serializes execution, so while statement k runs, the other
/// barrier-released clients all sit counted in `in_flight`.
#[test]
fn sustains_100_concurrent_statements_across_two_tenants() {
    const CLIENTS: usize = 128;
    let catalog = retail_catalog(20_000, 11);
    let server = start_server(
        catalog,
        AdmissionConfig {
            slots: 1,
            capacity_bytes: 1 << 20,
            policy: AdmissionPolicy::Fifo,
        },
    );
    let addr = server.addr();
    // A heavy statement keeps each serialized execution long enough that
    // all clients pile up behind the gate before many can drain.
    let heavy = SERVING_CATALOG.iter().find(|s| s.heavy).unwrap();

    let barrier = Arc::new(Barrier::new(CLIENTS));
    let handles: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let tenant = format!("tenant-{}", c % 2);
                let mut client = ServeClient::connect(addr, &tenant).unwrap();
                client.set_read_timeout(Some(Duration::from_secs(300))).unwrap();
                // Everyone is connected and handshaken before anyone sends.
                barrier.wait();
                match client.query(heavy.sql, 0).unwrap() {
                    ServeReply::Rows { rows, .. } => rows.num_rows(),
                    other => panic!("client {c} denied: {other:?}"),
                }
            })
        })
        .collect();
    for h in handles {
        assert!(h.join().expect("load client panicked") > 0);
    }

    let tenants = server.tenant_stats();
    let snap = server.shutdown();
    assert_eq!(snap.queries, CLIENTS as u64);
    assert_eq!(snap.completed, CLIENTS as u64);
    assert_eq!(snap.lost(), 0, "lost statements: {snap:?}");
    assert_eq!(snap.worker_panics, 0);
    assert!(
        snap.peak_in_flight >= 100,
        "peak in-flight {} never reached 100",
        snap.peak_in_flight
    );
    assert_eq!(tenants.len(), 2, "expected exactly two tenants");
    for (name, t) in &tenants {
        assert!(t.accounted(), "tenant {name} ledger unbalanced: {t:?}");
        assert_eq!(t.submitted, (CLIENTS / 2) as u64, "tenant {name}");
        assert_eq!(t.completed, (CLIENTS / 2) as u64, "tenant {name}");
    }
}

/// One seeded load run: returns everything schedule-determined — the
/// exact plan, the client-side ledger, the per-tenant server stats, and
/// the whole-server counters (timing-dependent fields zeroed).
fn seeded_run(
    cfg: &LoadConfig,
) -> (
    Vec<snowpark::sim::ClientPlan>,
    std::collections::BTreeMap<String, snowpark::sim::TenantOutcomes>,
    Vec<(String, TenantSnapshot)>,
    snowpark::server::CountersSnapshot,
) {
    let catalog = retail_catalog(4_000, 13);
    let server = start_server(
        catalog,
        AdmissionConfig {
            slots: 2,
            capacity_bytes: 2 << 20,
            policy: AdmissionPolicy::Backfill,
        },
    );
    let plan = snowpark::sim::plan_load(SERVING_CATALOG.len(), cfg);
    let report = run_load(server.addr(), SERVING_CATALOG, cfg).unwrap();
    assert!(report.accounted(), "client ledger unbalanced");
    assert_eq!(report.protocol_errors(), 0, "protocol failures during load");
    assert_eq!(
        report.sent(),
        (cfg.clients * cfg.requests_per_client) as u64,
        "harness dropped planned statements"
    );
    let tenants: Vec<(String, TenantSnapshot)> = server
        .tenant_stats()
        .into_iter()
        .map(|(name, snap)| (name, snap.deterministic()))
        .collect();
    let counters = server.shutdown();
    assert_eq!(counters.worker_panics, 0);
    assert_eq!(counters.lost(), 0);
    (plan, report.deterministic(), tenants, counters.deterministic())
}

/// Same seed → identical arrival schedule, identical per-tenant outcome
/// counts, identical server-side accounting. (Latencies are excluded —
/// they are wall-clock facts, not schedule facts.)
#[test]
fn load_harness_is_deterministic_for_a_fixed_seed() {
    let cfg = LoadConfig {
        tenants: 2,
        clients: 8,
        requests_per_client: 6,
        arrival: Arrival::Closed { think_ms: 0 },
        zipf_s: 1.1,
        seed: 42,
        timeout_ms: 0,
    };
    let (plan_a, ledger_a, tenants_a, counters_a) = seeded_run(&cfg);
    let (plan_b, ledger_b, tenants_b, counters_b) = seeded_run(&cfg);

    assert_eq!(plan_a, plan_b, "same seed must plan the same schedule");
    assert_eq!(ledger_a, ledger_b, "per-tenant outcome counts diverged");
    assert_eq!(tenants_a, tenants_b, "server tenant stats diverged");
    assert_eq!(counters_a, counters_b, "server counters diverged");

    // And a different seed really does produce a different schedule.
    let other = snowpark::sim::plan_load(SERVING_CATALOG.len(), &LoadConfig { seed: 43, ..cfg });
    assert_ne!(plan_a, other, "seed is not wired through the planner");
}
