//! Historical per-UDF execution statistics.
//!
//! §IV.C: "we examine the workload's per-row execution time from
//! historical stats and define a threshold (T) to determine whether it is
//! worth row level redistribution." This store tracks an exponentially
//! weighted per-row cost per UDF, fed by the interpreter pool after each
//! batch.

use std::collections::HashMap;
use std::sync::Mutex;

/// Aggregated execution stats for one UDF.
#[derive(Debug, Clone, Default)]
pub struct UdfStats {
    /// EWMA of per-row execution time in nanoseconds.
    pub ewma_row_ns: f64,
    /// Total rows processed (all time).
    pub total_rows: u64,
    /// Total batches processed.
    pub total_batches: u64,
}

/// Thread-safe store of per-UDF stats.
#[derive(Debug)]
pub struct UdfStatsStore {
    inner: Mutex<HashMap<String, UdfStats>>,
    /// EWMA smoothing factor.
    alpha: f64,
}

/// Same as [`UdfStatsStore::new`]. (The derived `Default` used to zero
/// `alpha`, which froze the EWMA at its first sample — every later
/// `record_batch` contributed `alpha * per_row = 0`, so
/// `should_redistribute` never adapted to observed cost.)
impl Default for UdfStatsStore {
    fn default() -> Self {
        Self::new()
    }
}

impl UdfStatsStore {
    pub fn new() -> Self {
        Self { inner: Mutex::new(HashMap::new()), alpha: 0.3 }
    }

    /// Record one executed batch: `rows` rows in `elapsed_ns` total.
    pub fn record_batch(&self, udf: &str, rows: u64, elapsed_ns: u64) {
        if rows == 0 {
            return;
        }
        let per_row = elapsed_ns as f64 / rows as f64;
        let mut inner = self.inner.lock().unwrap();
        let e = inner.entry(udf.to_string()).or_default();
        if e.total_batches == 0 {
            e.ewma_row_ns = per_row;
        } else {
            e.ewma_row_ns = self.alpha * per_row + (1.0 - self.alpha) * e.ewma_row_ns;
        }
        e.total_rows += rows;
        e.total_batches += 1;
    }

    /// Historical per-row cost, if any executions have been observed.
    pub fn row_cost_ns(&self, udf: &str) -> Option<f64> {
        let inner = self.inner.lock().unwrap();
        inner
            .get(udf)
            .filter(|s| s.total_batches > 0)
            .map(|s| s.ewma_row_ns)
    }

    pub fn get(&self, udf: &str) -> Option<UdfStats> {
        self.inner.lock().unwrap().get(udf).cloned()
    }

    pub fn clear(&self) {
        self.inner.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_batch_seeds_ewma() {
        let s = UdfStatsStore::new();
        assert_eq!(s.row_cost_ns("f"), None);
        s.record_batch("f", 100, 1_000_000); // 10µs/row
        assert_eq!(s.row_cost_ns("f"), Some(10_000.0));
    }

    #[test]
    fn ewma_moves_toward_new_observations() {
        let s = UdfStatsStore::new();
        s.record_batch("f", 100, 1_000_000); // 10µs/row
        s.record_batch("f", 100, 3_000_000); // 30µs/row
        let v = s.row_cost_ns("f").unwrap();
        assert!(v > 10_000.0 && v < 30_000.0, "v={v}");
        let stats = s.get("f").unwrap();
        assert_eq!(stats.total_rows, 200);
        assert_eq!(stats.total_batches, 2);
    }

    #[test]
    fn zero_row_batches_ignored() {
        let s = UdfStatsStore::new();
        s.record_batch("f", 0, 500);
        assert_eq!(s.row_cost_ns("f"), None);
    }

    #[test]
    fn default_store_ewma_adapts() {
        // Regression: the derived Default left `alpha = 0.0`, freezing
        // the EWMA at its first sample.
        let s = UdfStatsStore::default();
        s.record_batch("f", 100, 1_000_000); // 10µs/row
        s.record_batch("f", 100, 3_000_000); // 30µs/row
        let v = s.row_cost_ns("f").unwrap();
        assert!(v > 10_000.0, "EWMA frozen at first sample: {v}");
        assert!(v < 30_000.0, "{v}");
    }

    #[test]
    fn per_udf_isolation() {
        let s = UdfStatsStore::new();
        s.record_batch("a", 10, 10_000);
        s.record_batch("b", 10, 99_000);
        assert!((s.row_cost_ns("a").unwrap() - 1_000.0).abs() < 1e-9);
        assert!((s.row_cost_ns("b").unwrap() - 9_900.0).abs() < 1e-9);
    }
}
