//! UDF registry: definitions for scalar / vectorized / table / aggregate
//! user functions.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::types::{DataType, RowSet, Schema, Value};

/// A scalar UDF body: one row of argument values in, one value out.
/// This models the paper's row-at-a-time Python UDF.
pub type ScalarFn = Arc<dyn Fn(&[Value]) -> Result<Value> + Send + Sync>;

/// A vectorized UDF body: a batch of argument columns in (as a RowSet),
/// one output column of f64 values out. Models the paper's vectorized
/// (Pandas-DataFrame) UDF interface; the XLA-backed implementations
/// (`runtime::kernels`) plug in through this same type.
pub type VectorizedFn = Arc<dyn Fn(&RowSet) -> Result<Vec<f64>> + Send + Sync>;

/// UDTF: rows of argument values in, a table out.
pub type UdtfFn = Arc<dyn Fn(&[Value]) -> Result<RowSet> + Send + Sync>;

/// UDAF incremental state.
///
/// States are `Send` so the morsel-parallel aggregate can build one state
/// per group on each worker thread; the engine then folds the
/// thread-local states with [`UdafState::merge`] in row-range order.
pub trait UdafState: Send {
    /// Fold one row of argument values into the state.
    fn update(&mut self, args: &[Value]) -> Result<()>;
    /// Merge another state of the same UDAF into this one (parallel
    /// partial aggregation). States merge in input scan order, and
    /// merging into a freshly-created state must be equivalent to
    /// adopting `other`, so `merge` must behave like
    /// "`update` everything `other` saw, after everything I saw".
    fn merge(&mut self, other: Box<dyn UdafState>) -> Result<()>;
    /// Produce the aggregate value for everything folded in so far.
    fn finish(&self) -> Result<Value>;
    /// Downcast hook so `merge` implementations can reach the concrete
    /// state type of `other`.
    fn as_any(&self) -> &dyn std::any::Any;
}

/// Factory producing fresh UDAF states.
pub type UdafFactory = Arc<dyn Fn() -> Box<dyn UdafState> + Send + Sync>;

/// What kind of UDF a name refers to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UdfKind {
    Scalar,
    Vectorized,
    Table,
    Aggregate,
}

/// A registered scalar UDF.
#[derive(Clone)]
pub struct Udf {
    pub name: String,
    pub return_type: DataType,
    pub body: ScalarFn,
    /// Estimated per-row cost in nanoseconds, used to seed the §IV.C
    /// redistribution decision before history exists.
    pub est_row_cost_ns: u64,
    /// Packages this UDF imports (drives the §IV.A package-cache path).
    pub packages: Vec<String>,
}

/// A registered vectorized UDF.
#[derive(Clone)]
pub struct VectorizedUdf {
    pub name: String,
    pub return_type: DataType,
    pub body: VectorizedFn,
    pub packages: Vec<String>,
}

/// A registered table function.
#[derive(Clone)]
pub struct Udtf {
    pub name: String,
    pub schema: Schema,
    pub body: UdtfFn,
    pub packages: Vec<String>,
}

/// A registered aggregate function.
#[derive(Clone)]
pub struct Udaf {
    pub name: String,
    pub return_type: DataType,
    pub factory: UdafFactory,
    pub packages: Vec<String>,
}

/// The registry: one namespace per function kind, like Snowflake's
/// function catalog.
#[derive(Default, Clone)]
pub struct UdfRegistry {
    scalars: HashMap<String, Udf>,
    vectorized: HashMap<String, VectorizedUdf>,
    tables: HashMap<String, Udtf>,
    aggregates: HashMap<String, Udaf>,
}

impl UdfRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn register_scalar(
        &mut self,
        name: &str,
        return_type: DataType,
        body: ScalarFn,
    ) -> &mut Udf {
        let name = name.to_ascii_lowercase();
        self.scalars.insert(
            name.clone(),
            Udf {
                name: name.clone(),
                return_type,
                body,
                est_row_cost_ns: 1_000,
                packages: Vec::new(),
            },
        );
        self.scalars.get_mut(&name).unwrap()
    }

    pub fn register_vectorized(
        &mut self,
        name: &str,
        return_type: DataType,
        body: VectorizedFn,
    ) {
        let name = name.to_ascii_lowercase();
        self.vectorized.insert(
            name.clone(),
            VectorizedUdf { name, return_type, body, packages: Vec::new() },
        );
    }

    pub fn register_udtf(&mut self, name: &str, schema: Schema, body: UdtfFn) {
        let name = name.to_ascii_lowercase();
        self.tables
            .insert(name.clone(), Udtf { name, schema, body, packages: Vec::new() });
    }

    pub fn register_udaf(&mut self, name: &str, return_type: DataType, factory: UdafFactory) {
        let name = name.to_ascii_lowercase();
        self.aggregates.insert(
            name.clone(),
            Udaf { name, return_type, factory, packages: Vec::new() },
        );
    }

    /// Attach required packages to a registered function (any kind).
    pub fn set_packages(&mut self, name: &str, packages: &[&str]) {
        let name = name.to_ascii_lowercase();
        let pkgs: Vec<String> = packages.iter().map(|s| s.to_string()).collect();
        if let Some(u) = self.scalars.get_mut(&name) {
            u.packages = pkgs.clone();
        }
        if let Some(u) = self.vectorized.get_mut(&name) {
            u.packages = pkgs.clone();
        }
        if let Some(u) = self.tables.get_mut(&name) {
            u.packages = pkgs.clone();
        }
        if let Some(u) = self.aggregates.get_mut(&name) {
            u.packages = pkgs;
        }
    }

    /// Set the estimated per-row cost of a scalar UDF (nanoseconds).
    pub fn set_row_cost(&mut self, name: &str, ns: u64) {
        if let Some(u) = self.scalars.get_mut(&name.to_ascii_lowercase()) {
            u.est_row_cost_ns = ns;
        }
    }

    pub fn kind_of(&self, name: &str) -> Option<UdfKind> {
        let name = name.to_ascii_lowercase();
        if self.scalars.contains_key(&name) {
            Some(UdfKind::Scalar)
        } else if self.vectorized.contains_key(&name) {
            Some(UdfKind::Vectorized)
        } else if self.tables.contains_key(&name) {
            Some(UdfKind::Table)
        } else if self.aggregates.contains_key(&name) {
            Some(UdfKind::Aggregate)
        } else {
            None
        }
    }

    pub fn has_scalar(&self, name: &str) -> bool {
        self.scalars.contains_key(&name.to_ascii_lowercase())
    }

    pub fn has_vectorized(&self, name: &str) -> bool {
        self.vectorized.contains_key(&name.to_ascii_lowercase())
    }

    pub fn has_udaf(&self, name: &str) -> bool {
        self.aggregates.contains_key(&name.to_ascii_lowercase())
    }

    pub fn scalar(&self, name: &str) -> Option<&Udf> {
        self.scalars.get(&name.to_ascii_lowercase())
    }

    pub fn vectorized(&self, name: &str) -> Option<&VectorizedUdf> {
        self.vectorized.get(&name.to_ascii_lowercase())
    }

    pub fn udtf(&self, name: &str) -> Option<&Udtf> {
        self.tables.get(&name.to_ascii_lowercase())
    }

    pub fn udaf(&self, name: &str) -> Option<&Udaf> {
        self.aggregates.get(&name.to_ascii_lowercase())
    }

    pub fn call_scalar(&self, name: &str, args: &[Value]) -> Result<Value> {
        let udf = self
            .scalar(name)
            .ok_or_else(|| anyhow!("no scalar UDF named {name:?}"))?;
        (udf.body)(args)
    }

    pub fn call_udtf(&self, name: &str, args: &[Value]) -> Result<RowSet> {
        let udtf = self
            .udtf(name)
            .ok_or_else(|| anyhow!("no UDTF named {name:?}"))?;
        let out = (udtf.body)(args)?;
        if out.schema != udtf.schema {
            bail!("UDTF {name:?} returned a rowset with an unexpected schema");
        }
        Ok(out)
    }

    pub fn scalar_return_type(&self, name: &str) -> Option<DataType> {
        let name = name.to_ascii_lowercase();
        self.scalars
            .get(&name)
            .map(|u| u.return_type)
            .or_else(|| self.vectorized.get(&name).map(|u| u.return_type))
            .or_else(|| self.aggregates.get(&name).map(|u| u.return_type))
    }

    /// Union of packages required by the given function names — the input
    /// to the §IV.A package solving/caching pipeline for a query.
    pub fn packages_for(&self, names: &[String]) -> Vec<String> {
        let mut pkgs: Vec<String> = Vec::new();
        for n in names {
            let n = n.to_ascii_lowercase();
            let list = self
                .scalars
                .get(&n)
                .map(|u| &u.packages)
                .or_else(|| self.vectorized.get(&n).map(|u| &u.packages))
                .or_else(|| self.tables.get(&n).map(|u| &u.packages))
                .or_else(|| self.aggregates.get(&n).map(|u| &u.packages));
            if let Some(list) = list {
                for p in list {
                    if !pkgs.contains(p) {
                        pkgs.push(p.clone());
                    }
                }
            }
        }
        pkgs.sort();
        pkgs
    }

    pub fn names(&self) -> Vec<String> {
        let mut names: Vec<String> = self
            .scalars
            .keys()
            .chain(self.vectorized.keys())
            .chain(self.tables.keys())
            .chain(self.aggregates.keys())
            .cloned()
            .collect();
        names.sort();
        names
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::Field;

    fn registry() -> UdfRegistry {
        let mut r = UdfRegistry::new();
        r.register_scalar(
            "double_it",
            DataType::Float64,
            Arc::new(|args| {
                let x = args[0].as_f64().unwrap_or(0.0);
                Ok(Value::Float(x * 2.0))
            }),
        );
        r
    }

    #[test]
    fn scalar_registration_and_call() {
        let r = registry();
        assert!(r.has_scalar("double_it"));
        assert!(r.has_scalar("DOUBLE_IT")); // case-insensitive
        assert_eq!(r.kind_of("double_it"), Some(UdfKind::Scalar));
        let v = r.call_scalar("double_it", &[Value::Float(3.0)]).unwrap();
        assert_eq!(v, Value::Float(6.0));
        assert!(r.call_scalar("missing", &[]).is_err());
    }

    #[test]
    fn udtf_schema_enforced() {
        let mut r = UdfRegistry::new();
        let schema = Schema::new(vec![Field::new("n", DataType::Int64)]);
        let schema2 = schema.clone();
        r.register_udtf(
            "range_table",
            schema,
            Arc::new(move |args| {
                let n = args[0].as_i64().unwrap_or(0);
                let col = crate::types::Column::from_i64((0..n).collect());
                RowSet::new(schema2.clone(), vec![col])
            }),
        );
        let out = r.call_udtf("range_table", &[Value::Int(4)]).unwrap();
        assert_eq!(out.num_rows(), 4);
    }

    #[test]
    fn packages_union_sorted_dedup() {
        let mut r = registry();
        r.set_packages("double_it", &["numpy", "pandas"]);
        r.register_scalar(
            "other",
            DataType::Float64,
            Arc::new(|_| Ok(Value::Null)),
        );
        r.set_packages("other", &["numpy", "scikit-learn"]);
        let pkgs = r.packages_for(&["double_it".into(), "other".into()]);
        assert_eq!(pkgs, vec!["numpy", "pandas", "scikit-learn"]);
    }

    #[test]
    fn row_cost_settable() {
        let mut r = registry();
        r.set_row_cost("double_it", 50_000);
        assert_eq!(r.scalar("double_it").unwrap().est_row_cost_ns, 50_000);
    }
}
