//! User-defined function framework (§III.A): scalar UDFs (per-row),
//! vectorized UDFs (per-batch, Pandas-style — here backed by the AOT
//! XLA kernels), table functions (UDTFs), and aggregate functions (UDAFs).
//!
//! The registry stores definitions; execution happens either inline (for
//! expression evaluation) or through the warehouse interpreter pool (the
//! `warehouse::interp` module), which is where the §IV.C redistribution
//! decision lives. UDAF states additionally support [`UdafState::merge`],
//! which the engine's morsel-parallel aggregate uses to fold thread-local
//! partial states into the final per-group value.

mod registry;
mod stats;

pub use registry::{
    ScalarFn, Udaf, UdafFactory, UdafState, Udf, UdfKind, UdfRegistry, Udtf, VectorizedFn,
};
pub use stats::{UdfStats, UdfStatsStore};
