//! `snowparkd` — leader entrypoint + CLI for the Snowpark reproduction.

fn main() {
    snowpark::cli::main();
}
