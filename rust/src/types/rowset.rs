//! Columnar rowsets — the unit of data exchange between operators and the
//! unit shipped to interpreter processes (§III.B: "worker threads
//! communicate with the Snowpark Python interpreter processes ... to pass
//! rowsets for computation").
//!
//! Columns are typed vectors with an optional validity mask; a `RowSet`
//! bundles columns with a schema. All engine operators are vectorized over
//! rowsets; per-row access exists for the scalar-UDF path.

use std::fmt;

use anyhow::{anyhow, bail, Result};

use super::value::{DataType, Schema, Value};

/// A typed column with validity. `valid[i] == false` means NULL (`None`
/// means every row is valid).
#[derive(Debug, Clone, PartialEq)]
pub enum Column {
    /// 64-bit integer column.
    Int64 {
        /// Cell payloads (NULL slots hold `0`).
        data: Vec<i64>,
        /// Validity mask; `None` = all rows valid.
        valid: Option<Vec<bool>>,
    },
    /// 64-bit float column.
    Float64 {
        /// Cell payloads (NULL slots hold `0.0`).
        data: Vec<f64>,
        /// Validity mask; `None` = all rows valid.
        valid: Option<Vec<bool>>,
    },
    /// UTF-8 string column.
    Utf8 {
        /// Cell payloads (NULL slots hold `""`).
        data: Vec<String>,
        /// Validity mask; `None` = all rows valid.
        valid: Option<Vec<bool>>,
    },
    /// Boolean column.
    Bool {
        /// Cell payloads (NULL slots hold `false`).
        data: Vec<bool>,
        /// Validity mask; `None` = all rows valid.
        valid: Option<Vec<bool>>,
    },
}

impl Column {
    /// The column's logical type.
    pub fn data_type(&self) -> DataType {
        match self {
            Column::Int64 { .. } => DataType::Int64,
            Column::Float64 { .. } => DataType::Float64,
            Column::Utf8 { .. } => DataType::Utf8,
            Column::Bool { .. } => DataType::Bool,
        }
    }

    /// Number of rows.
    pub fn len(&self) -> usize {
        match self {
            Column::Int64 { data, .. } => data.len(),
            Column::Float64 { data, .. } => data.len(),
            Column::Utf8 { data, .. } => data.len(),
            Column::Bool { data, .. } => data.len(),
        }
    }

    /// True when the column has no rows.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// All-valid Int64 column from raw data.
    pub fn from_i64(data: Vec<i64>) -> Self {
        Column::Int64 { data, valid: None }
    }

    /// All-valid Float64 column from raw data.
    pub fn from_f64(data: Vec<f64>) -> Self {
        Column::Float64 { data, valid: None }
    }

    /// All-valid Utf8 column from raw data.
    pub fn from_strings(data: Vec<String>) -> Self {
        Column::Utf8 { data, valid: None }
    }

    /// All-valid Bool column from raw data.
    pub fn from_bools(data: Vec<bool>) -> Self {
        Column::Bool { data, valid: None }
    }

    /// Zero-row column of the given type.
    pub fn empty(dt: DataType) -> Self {
        match dt {
            DataType::Int64 => Column::Int64 { data: vec![], valid: None },
            DataType::Float64 => Column::Float64 { data: vec![], valid: None },
            DataType::Utf8 => Column::Utf8 { data: vec![], valid: None },
            DataType::Bool => Column::Bool { data: vec![], valid: None },
        }
    }

    /// Is row `idx` non-NULL?
    #[inline]
    pub fn is_valid(&self, idx: usize) -> bool {
        let valid = match self {
            Column::Int64 { valid, .. } => valid,
            Column::Float64 { valid, .. } => valid,
            Column::Utf8 { valid, .. } => valid,
            Column::Bool { valid, .. } => valid,
        };
        valid.as_ref().map_or(true, |v| v[idx])
    }

    /// Scalar view of one cell.
    pub fn value(&self, idx: usize) -> Value {
        if !self.is_valid(idx) {
            return Value::Null;
        }
        match self {
            Column::Int64 { data, .. } => Value::Int(data[idx]),
            Column::Float64 { data, .. } => Value::Float(data[idx]),
            Column::Utf8 { data, .. } => Value::Str(data[idx].clone()),
            Column::Bool { data, .. } => Value::Bool(data[idx]),
        }
    }

    /// Fast typed accessor for vectorized paths (no Value allocation):
    /// the raw f64 payloads, if this is a Float64 column.
    pub fn f64_data(&self) -> Option<&[f64]> {
        match self {
            Column::Float64 { data, .. } => Some(data),
            _ => None,
        }
    }

    /// Raw i64 payloads, if this is an Int64 column.
    pub fn i64_data(&self) -> Option<&[i64]> {
        match self {
            Column::Int64 { data, .. } => Some(data),
            _ => None,
        }
    }

    /// Raw string payloads, if this is a Utf8 column.
    pub fn str_data(&self) -> Option<&[String]> {
        match self {
            Column::Utf8 { data, .. } => Some(data),
            _ => None,
        }
    }

    /// Raw bool payloads, if this is a Bool column.
    pub fn bool_data(&self) -> Option<&[bool]> {
        match self {
            Column::Bool { data, .. } => Some(data),
            _ => None,
        }
    }

    /// Validity mask, if any row is NULL (`None` = all rows valid).
    pub fn validity(&self) -> Option<&[bool]> {
        match self {
            Column::Int64 { valid, .. } => valid.as_deref(),
            Column::Float64 { valid, .. } => valid.as_deref(),
            Column::Utf8 { valid, .. } => valid.as_deref(),
            Column::Bool { valid, .. } => valid.as_deref(),
        }
    }

    /// Typed gather with NULL padding: index `-1` yields a NULL cell.
    /// Copies raw buffers directly — no per-cell `Value` round trip.
    pub fn gather_opt(&self, indices: &[i64]) -> Column {
        fn gathered<T: Clone + Default>(
            data: &[T],
            valid: Option<&[bool]>,
            indices: &[i64],
        ) -> (Vec<T>, Option<Vec<bool>>) {
            let mut out = Vec::with_capacity(indices.len());
            let mut mask = Vec::with_capacity(indices.len());
            let mut any_null = false;
            for &i in indices {
                if i < 0 {
                    out.push(T::default());
                    mask.push(false);
                    any_null = true;
                } else {
                    let i = i as usize;
                    let ok = valid.map_or(true, |v| v[i]);
                    any_null |= !ok;
                    out.push(if ok { data[i].clone() } else { T::default() });
                    mask.push(ok);
                }
            }
            (out, if any_null { Some(mask) } else { None })
        }
        match self {
            Column::Int64 { data, valid } => {
                let (data, valid) = gathered(data, valid.as_deref(), indices);
                Column::Int64 { data, valid }
            }
            Column::Float64 { data, valid } => {
                let (data, valid) = gathered(data, valid.as_deref(), indices);
                Column::Float64 { data, valid }
            }
            Column::Utf8 { data, valid } => {
                let (data, valid) = gathered(data, valid.as_deref(), indices);
                Column::Utf8 { data, valid }
            }
            Column::Bool { data, valid } => {
                let (data, valid) = gathered(data, valid.as_deref(), indices);
                Column::Bool { data, valid }
            }
        }
    }

    /// Lossy f32 view for the XLA marshalling path (Int64/Float64 only).
    pub fn to_f32_vec(&self) -> Result<Vec<f32>> {
        match self {
            Column::Float64 { data, .. } => Ok(data.iter().map(|&v| v as f32).collect()),
            Column::Int64 { data, .. } => Ok(data.iter().map(|&v| v as f32).collect()),
            other => bail!("cannot marshal {:?} column to f32", other.data_type()),
        }
    }

    /// Build a value-by-value column of the given type.
    pub fn from_values(dt: DataType, values: &[Value]) -> Result<Self> {
        let n = values.len();
        let mut valid = vec![true; n];
        let mut any_null = false;
        let col = match dt {
            DataType::Int64 => {
                let mut data = Vec::with_capacity(n);
                for (i, v) in values.iter().enumerate() {
                    match v {
                        Value::Null => {
                            valid[i] = false;
                            any_null = true;
                            data.push(0);
                        }
                        other => data.push(
                            other
                                .as_i64()
                                .ok_or_else(|| anyhow!("expected INT, got {other}"))?,
                        ),
                    }
                }
                Column::Int64 { data, valid: any_null.then_some(valid) }
            }
            DataType::Float64 => {
                let mut data = Vec::with_capacity(n);
                for (i, v) in values.iter().enumerate() {
                    match v {
                        Value::Null => {
                            valid[i] = false;
                            any_null = true;
                            data.push(0.0);
                        }
                        other => data.push(
                            other
                                .as_f64()
                                .ok_or_else(|| anyhow!("expected DOUBLE, got {other}"))?,
                        ),
                    }
                }
                Column::Float64 { data, valid: any_null.then_some(valid) }
            }
            DataType::Utf8 => {
                let mut data = Vec::with_capacity(n);
                for (i, v) in values.iter().enumerate() {
                    match v {
                        Value::Null => {
                            valid[i] = false;
                            any_null = true;
                            data.push(String::new());
                        }
                        Value::Str(s) => data.push(s.clone()),
                        other => data.push(other.to_string()),
                    }
                }
                Column::Utf8 { data, valid: any_null.then_some(valid) }
            }
            DataType::Bool => {
                let mut data = Vec::with_capacity(n);
                for (i, v) in values.iter().enumerate() {
                    match v {
                        Value::Null => {
                            valid[i] = false;
                            any_null = true;
                            data.push(false);
                        }
                        other => data.push(
                            other
                                .as_bool()
                                .ok_or_else(|| anyhow!("expected BOOLEAN, got {other}"))?,
                        ),
                    }
                }
                Column::Bool { data, valid: any_null.then_some(valid) }
            }
        };
        Ok(col)
    }

    /// Select the rows where `mask` is true.
    pub fn filter(&self, mask: &[bool]) -> Column {
        assert_eq!(mask.len(), self.len());
        let idx: Vec<usize> = mask
            .iter()
            .enumerate()
            .filter_map(|(i, &keep)| keep.then_some(i))
            .collect();
        self.take(&idx)
    }

    /// Gather rows by index.
    pub fn take(&self, indices: &[usize]) -> Column {
        fn take_valid(valid: &Option<Vec<bool>>, idx: &[usize]) -> Option<Vec<bool>> {
            valid
                .as_ref()
                .map(|v| idx.iter().map(|&i| v[i]).collect())
        }
        match self {
            Column::Int64 { data, valid } => Column::Int64 {
                data: indices.iter().map(|&i| data[i]).collect(),
                valid: take_valid(valid, indices),
            },
            Column::Float64 { data, valid } => Column::Float64 {
                data: indices.iter().map(|&i| data[i]).collect(),
                valid: take_valid(valid, indices),
            },
            Column::Utf8 { data, valid } => Column::Utf8 {
                data: indices.iter().map(|&i| data[i].clone()).collect(),
                valid: take_valid(valid, indices),
            },
            Column::Bool { data, valid } => Column::Bool {
                data: indices.iter().map(|&i| data[i]).collect(),
                valid: take_valid(valid, indices),
            },
        }
    }

    /// Zero-extend this column with the rows of `other` (same type).
    pub fn append(&mut self, other: &Column) -> Result<()> {
        if self.data_type() != other.data_type() {
            bail!(
                "append type mismatch: {:?} vs {:?}",
                self.data_type(),
                other.data_type()
            );
        }
        let self_len = self.len();
        let other_len = other.len();
        fn merge_valid(
            a: &mut Option<Vec<bool>>,
            b: &Option<Vec<bool>>,
            a_len: usize,
            b_len: usize,
        ) {
            if a.is_none() && b.is_none() {
                return;
            }
            let mut v = a.take().unwrap_or_else(|| vec![true; a_len]);
            match b {
                Some(bv) => v.extend_from_slice(bv),
                None => v.extend(std::iter::repeat(true).take(b_len)),
            }
            *a = Some(v);
        }
        match (self, other) {
            (Column::Int64 { data: a, valid: va }, Column::Int64 { data: b, valid: vb }) => {
                merge_valid(va, vb, self_len, other_len);
                a.extend_from_slice(b);
            }
            (Column::Float64 { data: a, valid: va }, Column::Float64 { data: b, valid: vb }) => {
                merge_valid(va, vb, self_len, other_len);
                a.extend_from_slice(b);
            }
            (Column::Utf8 { data: a, valid: va }, Column::Utf8 { data: b, valid: vb }) => {
                merge_valid(va, vb, self_len, other_len);
                a.extend_from_slice(b);
            }
            (Column::Bool { data: a, valid: va }, Column::Bool { data: b, valid: vb }) => {
                merge_valid(va, vb, self_len, other_len);
                a.extend_from_slice(b);
            }
            _ => unreachable!("type equality checked above"),
        }
        Ok(())
    }

    /// Contiguous slice [offset, offset+len). Copies the ranges directly
    /// — no index-vector materialization — since this sits on the
    /// per-morsel hot path of parallel expression evaluation.
    pub fn slice(&self, offset: usize, len: usize) -> Column {
        fn sub(valid: &Option<Vec<bool>>, offset: usize, len: usize) -> Option<Vec<bool>> {
            valid.as_ref().map(|v| v[offset..offset + len].to_vec())
        }
        match self {
            Column::Int64 { data, valid } => Column::Int64 {
                data: data[offset..offset + len].to_vec(),
                valid: sub(valid, offset, len),
            },
            Column::Float64 { data, valid } => Column::Float64 {
                data: data[offset..offset + len].to_vec(),
                valid: sub(valid, offset, len),
            },
            Column::Utf8 { data, valid } => Column::Utf8 {
                data: data[offset..offset + len].to_vec(),
                valid: sub(valid, offset, len),
            },
            Column::Bool { data, valid } => Column::Bool {
                data: data[offset..offset + len].to_vec(),
                valid: sub(valid, offset, len),
            },
        }
    }

    /// Approximate in-memory footprint in bytes (for memory accounting).
    pub fn byte_size(&self) -> u64 {
        let base = match self {
            Column::Int64 { data, .. } => data.len() * 8,
            Column::Float64 { data, .. } => data.len() * 8,
            Column::Utf8 { data, .. } => data.iter().map(|s| s.len() + 24).sum(),
            Column::Bool { data, .. } => data.len(),
        };
        base as u64
    }
}

/// A batch of rows in columnar layout.
#[derive(Debug, Clone, PartialEq)]
pub struct RowSet {
    /// Field names and types, one per column.
    pub schema: Schema,
    /// The typed columns, all the same length.
    pub columns: Vec<Column>,
}

impl RowSet {
    /// Validated constructor: schema arity, column types, and row counts
    /// must line up.
    pub fn new(schema: Schema, columns: Vec<Column>) -> Result<Self> {
        if schema.len() != columns.len() {
            bail!(
                "schema has {} fields but {} columns given",
                schema.len(),
                columns.len()
            );
        }
        let mut len = None;
        for (f, c) in schema.fields.iter().zip(&columns) {
            if f.data_type != c.data_type() {
                bail!(
                    "column {} declared {} but is {:?}",
                    f.name,
                    f.data_type,
                    c.data_type()
                );
            }
            match len {
                None => len = Some(c.len()),
                Some(l) if l != c.len() => {
                    bail!("ragged rowset: {} vs {} rows", l, c.len())
                }
                _ => {}
            }
        }
        Ok(Self { schema, columns })
    }

    /// Zero-row rowset with the given schema.
    pub fn empty(schema: Schema) -> Self {
        let columns = schema
            .fields
            .iter()
            .map(|f| Column::empty(f.data_type))
            .collect();
        Self { schema, columns }
    }

    /// Number of rows.
    pub fn num_rows(&self) -> usize {
        self.columns.first().map_or(0, Column::len)
    }

    /// Number of columns.
    pub fn num_columns(&self) -> usize {
        self.columns.len()
    }

    /// Column by position.
    pub fn column(&self, idx: usize) -> &Column {
        &self.columns[idx]
    }

    /// Column by (case-insensitive) field name.
    pub fn column_by_name(&self, name: &str) -> Option<&Column> {
        self.schema.index_of(name).map(|i| &self.columns[i])
    }

    /// One row as scalars (scalar-UDF path, result printing).
    pub fn row(&self, idx: usize) -> Vec<Value> {
        self.columns.iter().map(|c| c.value(idx)).collect()
    }

    /// Select the rows where `mask` is true.
    pub fn filter(&self, mask: &[bool]) -> RowSet {
        RowSet {
            schema: self.schema.clone(),
            columns: self.columns.iter().map(|c| c.filter(mask)).collect(),
        }
    }

    /// Gather rows by index.
    pub fn take(&self, indices: &[usize]) -> RowSet {
        RowSet {
            schema: self.schema.clone(),
            columns: self.columns.iter().map(|c| c.take(indices)).collect(),
        }
    }

    /// Zero-copy-style gather through typed column buffers. With
    /// `null_pad`, index `-1` produces an all-NULL row (the outer-join
    /// padding case); without it, negative indices are a caller bug.
    pub fn gather(&self, indices: &[i64], null_pad: bool) -> RowSet {
        debug_assert!(
            null_pad || indices.iter().all(|&i| i >= 0),
            "negative gather index without null_pad"
        );
        RowSet {
            schema: self.schema.clone(),
            columns: self.columns.iter().map(|c| c.gather_opt(indices)).collect(),
        }
    }

    /// Contiguous row range `[offset, offset + len)`.
    pub fn slice(&self, offset: usize, len: usize) -> RowSet {
        RowSet {
            schema: self.schema.clone(),
            columns: self.columns.iter().map(|c| c.slice(offset, len)).collect(),
        }
    }

    /// Append all rows of `other` (schemas must match exactly).
    pub fn append(&mut self, other: &RowSet) -> Result<()> {
        if self.schema != other.schema {
            bail!("append schema mismatch");
        }
        for (a, b) in self.columns.iter_mut().zip(&other.columns) {
            a.append(b)?;
        }
        Ok(())
    }

    /// Split into batches of at most `batch_rows` rows.
    pub fn batches(&self, batch_rows: usize) -> Vec<RowSet> {
        assert!(batch_rows > 0);
        let n = self.num_rows();
        let mut out = Vec::with_capacity(n.div_ceil(batch_rows));
        let mut off = 0;
        while off < n {
            let len = batch_rows.min(n - off);
            out.push(self.slice(off, len));
            off += len;
        }
        out
    }

    /// Approximate in-memory footprint in bytes.
    pub fn byte_size(&self) -> u64 {
        self.columns.iter().map(Column::byte_size).sum()
    }
}

impl fmt::Display for RowSet {
    /// Pretty table (examples and the CLI REPL use this).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let names = self.schema.names();
        let mut widths: Vec<usize> = names.iter().map(|n| n.len()).collect();
        let n = self.num_rows().min(50);
        let mut rendered: Vec<Vec<String>> = Vec::with_capacity(n);
        for r in 0..n {
            let row: Vec<String> = self.row(r).iter().map(|v| v.to_string()).collect();
            for (w, cell) in widths.iter_mut().zip(&row) {
                *w = (*w).max(cell.len());
            }
            rendered.push(row);
        }
        let sep = |f: &mut fmt::Formatter<'_>| -> fmt::Result {
            write!(f, "+")?;
            for w in &widths {
                write!(f, "{}+", "-".repeat(w + 2))?;
            }
            writeln!(f)
        };
        sep(f)?;
        write!(f, "|")?;
        for (name, w) in names.iter().zip(&widths) {
            write!(f, " {name:<w$} |")?;
        }
        writeln!(f)?;
        sep(f)?;
        for row in &rendered {
            write!(f, "|")?;
            for (cell, w) in row.iter().zip(&widths) {
                write!(f, " {cell:<w$} |")?;
            }
            writeln!(f)?;
        }
        sep(f)?;
        if self.num_rows() > n {
            writeln!(f, "... {} more rows", self.num_rows() - n)?;
        }
        Ok(())
    }
}

/// A typed, growing column with validity — the unit [`RowSetBuilder`]
/// appends into.
#[derive(Debug)]
enum ColumnBuilder {
    Int64 { data: Vec<i64>, valid: Vec<bool>, any_null: bool },
    Float64 { data: Vec<f64>, valid: Vec<bool>, any_null: bool },
    Utf8 { data: Vec<String>, valid: Vec<bool>, any_null: bool },
    Bool { data: Vec<bool>, valid: Vec<bool>, any_null: bool },
}

impl ColumnBuilder {
    fn new(dt: DataType) -> ColumnBuilder {
        match dt {
            DataType::Int64 => {
                ColumnBuilder::Int64 { data: Vec::new(), valid: Vec::new(), any_null: false }
            }
            DataType::Float64 => {
                ColumnBuilder::Float64 { data: Vec::new(), valid: Vec::new(), any_null: false }
            }
            DataType::Utf8 => {
                ColumnBuilder::Utf8 { data: Vec::new(), valid: Vec::new(), any_null: false }
            }
            DataType::Bool => {
                ColumnBuilder::Bool { data: Vec::new(), valid: Vec::new(), any_null: false }
            }
        }
    }

    /// Append one cell. Conversions mirror [`Column::from_values`]; a
    /// value that cannot convert appends NULL (so lengths stay aligned)
    /// and returns the conversion error message.
    fn push(&mut self, v: Value) -> std::result::Result<(), String> {
        match self {
            ColumnBuilder::Int64 { data, valid, any_null } => match v {
                Value::Null => {
                    data.push(0);
                    valid.push(false);
                    *any_null = true;
                }
                other => match other.as_i64() {
                    Some(x) => {
                        data.push(x);
                        valid.push(true);
                    }
                    None => {
                        data.push(0);
                        valid.push(false);
                        *any_null = true;
                        return Err(format!("expected INT, got {other}"));
                    }
                },
            },
            ColumnBuilder::Float64 { data, valid, any_null } => match v {
                Value::Null => {
                    data.push(0.0);
                    valid.push(false);
                    *any_null = true;
                }
                other => match other.as_f64() {
                    Some(x) => {
                        data.push(x);
                        valid.push(true);
                    }
                    None => {
                        data.push(0.0);
                        valid.push(false);
                        *any_null = true;
                        return Err(format!("expected DOUBLE, got {other}"));
                    }
                },
            },
            ColumnBuilder::Utf8 { data, valid, any_null } => match v {
                Value::Null => {
                    data.push(String::new());
                    valid.push(false);
                    *any_null = true;
                }
                Value::Str(s) => {
                    data.push(s);
                    valid.push(true);
                }
                other => {
                    data.push(other.to_string());
                    valid.push(true);
                }
            },
            ColumnBuilder::Bool { data, valid, any_null } => match v {
                Value::Null => {
                    data.push(false);
                    valid.push(false);
                    *any_null = true;
                }
                other => match other.as_bool() {
                    Some(x) => {
                        data.push(x);
                        valid.push(true);
                    }
                    None => {
                        data.push(false);
                        valid.push(false);
                        *any_null = true;
                        return Err(format!("expected BOOLEAN, got {other}"));
                    }
                },
            },
        }
        Ok(())
    }

    fn finish(self) -> Column {
        match self {
            ColumnBuilder::Int64 { data, valid, any_null } => {
                Column::Int64 { data, valid: any_null.then_some(valid) }
            }
            ColumnBuilder::Float64 { data, valid, any_null } => {
                Column::Float64 { data, valid: any_null.then_some(valid) }
            }
            ColumnBuilder::Utf8 { data, valid, any_null } => {
                Column::Utf8 { data, valid: any_null.then_some(valid) }
            }
            ColumnBuilder::Bool { data, valid, any_null } => {
                Column::Bool { data, valid: any_null.then_some(valid) }
            }
        }
    }
}

/// Row-at-a-time builder (UDTF output, test fixtures, CSV ingest) that
/// appends every cell straight into typed column buffers — no
/// `Vec<Vec<Value>>` buffering and no second per-cell conversion pass at
/// [`RowSetBuilder::finish`]. Type errors are deferred to `finish`
/// (historical behavior): the offending slot becomes NULL and the first
/// conversion error is reported when the rowset is materialized.
#[derive(Debug)]
pub struct RowSetBuilder {
    schema: Schema,
    builders: Vec<ColumnBuilder>,
    len: usize,
    error: Option<String>,
}

impl RowSetBuilder {
    /// Empty builder for the given schema.
    pub fn new(schema: Schema) -> Self {
        let builders = schema
            .fields
            .iter()
            .map(|f| ColumnBuilder::new(f.data_type))
            .collect();
        Self { schema, builders, len: 0, error: None }
    }

    /// Append one row of scalars (arity-checked immediately; cell type
    /// errors are deferred to [`RowSetBuilder::finish`]).
    pub fn push(&mut self, row: Vec<Value>) -> Result<()> {
        if row.len() != self.schema.len() {
            bail!(
                "row has {} values, schema has {} fields",
                row.len(),
                self.schema.len()
            );
        }
        for (b, v) in self.builders.iter_mut().zip(row) {
            if let Err(e) = b.push(v) {
                if self.error.is_none() {
                    self.error = Some(e);
                }
            }
        }
        self.len += 1;
        Ok(())
    }

    /// Rows appended so far.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no row has been appended.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Materialize the rowset (no per-cell work left: the typed buffers
    /// move straight into the columns). Reports the first deferred cell
    /// conversion error, if any.
    pub fn finish(self) -> Result<RowSet> {
        if let Some(e) = self.error {
            bail!("{e}");
        }
        let columns = self.builders.into_iter().map(ColumnBuilder::finish).collect();
        RowSet::new(self.schema, columns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::Field;

    fn sample() -> RowSet {
        RowSet::new(
            Schema::new(vec![
                Field::new("id", DataType::Int64),
                Field::new("price", DataType::Float64),
                Field::new("name", DataType::Utf8),
            ]),
            vec![
                Column::from_i64(vec![1, 2, 3, 4]),
                Column::from_f64(vec![10.0, 20.0, 30.0, 40.0]),
                Column::from_strings(vec!["a".into(), "b".into(), "c".into(), "d".into()]),
            ],
        )
        .unwrap()
    }

    #[test]
    fn construction_validates() {
        let schema = Schema::new(vec![Field::new("x", DataType::Int64)]);
        assert!(RowSet::new(schema.clone(), vec![]).is_err()); // arity
        assert!(RowSet::new(schema.clone(), vec![Column::from_f64(vec![1.0])]).is_err()); // type
        let schema2 = Schema::new(vec![
            Field::new("x", DataType::Int64),
            Field::new("y", DataType::Int64),
        ]);
        assert!(RowSet::new(
            schema2,
            vec![Column::from_i64(vec![1]), Column::from_i64(vec![1, 2])]
        )
        .is_err()); // ragged
    }

    #[test]
    fn filter_take_slice() {
        let rs = sample();
        let filtered = rs.filter(&[true, false, true, false]);
        assert_eq!(filtered.num_rows(), 2);
        assert_eq!(filtered.column(0).value(1), Value::Int(3));

        let taken = rs.take(&[3, 0]);
        assert_eq!(taken.row(0), vec![
            Value::Int(4),
            Value::Float(40.0),
            Value::Str("d".into())
        ]);

        let sliced = rs.slice(1, 2);
        assert_eq!(sliced.num_rows(), 2);
        assert_eq!(sliced.column(0).value(0), Value::Int(2));
    }

    #[test]
    fn gather_with_null_padding() {
        let rs = sample();
        let gathered = rs.gather(&[2, -1, 0], true);
        assert_eq!(gathered.num_rows(), 3);
        assert_eq!(gathered.row(0), vec![
            Value::Int(3),
            Value::Float(30.0),
            Value::Str("c".into())
        ]);
        assert_eq!(gathered.row(1), vec![Value::Null, Value::Null, Value::Null]);
        assert_eq!(gathered.row(2), vec![
            Value::Int(1),
            Value::Float(10.0),
            Value::Str("a".into())
        ]);
        // Schema (and column types) survive the gather.
        assert_eq!(gathered.schema, rs.schema);
    }

    #[test]
    fn gather_opt_propagates_source_nulls() {
        let c = Column::Int64 { data: vec![1, 2, 3], valid: Some(vec![true, false, true]) };
        let g = c.gather_opt(&[1, 2, -1]);
        assert_eq!(g.value(0), Value::Null);
        assert_eq!(g.value(1), Value::Int(3));
        assert_eq!(g.value(2), Value::Null);
        // NULL slots are normalized to default payloads.
        assert_eq!(g, Column::Int64 { data: vec![0, 3, 0], valid: Some(vec![false, true, false]) });
    }

    #[test]
    fn validity_and_typed_accessors() {
        let c = Column::Int64 { data: vec![1, 2], valid: Some(vec![true, false]) };
        assert_eq!(c.validity(), Some(&[true, false][..]));
        assert_eq!(Column::from_i64(vec![1]).validity(), None);
        assert_eq!(
            Column::from_strings(vec!["a".into()]).str_data().map(|d| d.len()),
            Some(1)
        );
        assert_eq!(Column::from_bools(vec![true]).bool_data(), Some(&[true][..]));
    }

    #[test]
    fn append_and_batches() {
        let mut a = sample();
        let b = sample();
        a.append(&b).unwrap();
        assert_eq!(a.num_rows(), 8);
        let batches = a.batches(3);
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].num_rows(), 3);
        assert_eq!(batches[2].num_rows(), 2);
        let total: usize = batches.iter().map(RowSet::num_rows).sum();
        assert_eq!(total, 8);
    }

    #[test]
    fn nulls_round_trip_through_builder() {
        let schema = Schema::new(vec![
            Field::new("x", DataType::Int64),
            Field::new("s", DataType::Utf8),
        ]);
        let mut b = RowSetBuilder::new(schema);
        b.push(vec![Value::Int(1), Value::Null]).unwrap();
        b.push(vec![Value::Null, Value::Str("hi".into())]).unwrap();
        let rs = b.finish().unwrap();
        assert_eq!(rs.row(0), vec![Value::Int(1), Value::Null]);
        assert_eq!(rs.row(1), vec![Value::Null, Value::Str("hi".into())]);
    }

    #[test]
    fn builder_rejects_wrong_arity_and_type() {
        let schema = Schema::new(vec![Field::new("x", DataType::Int64)]);
        let mut b = RowSetBuilder::new(schema.clone());
        assert!(b.push(vec![Value::Int(1), Value::Int(2)]).is_err());
        let mut b = RowSetBuilder::new(schema);
        b.push(vec![Value::Str("nope".into())]).unwrap();
        assert!(b.finish().is_err());
    }

    #[test]
    fn f32_marshalling() {
        let c = Column::from_f64(vec![1.5, -2.5]);
        assert_eq!(c.to_f32_vec().unwrap(), vec![1.5f32, -2.5f32]);
        let c = Column::from_i64(vec![3]);
        assert_eq!(c.to_f32_vec().unwrap(), vec![3.0f32]);
        let c = Column::from_strings(vec!["x".into()]);
        assert!(c.to_f32_vec().is_err());
    }

    #[test]
    fn append_merges_validity() {
        let mut a = Column::from_i64(vec![1, 2]);
        let b = Column::Int64 { data: vec![3, 4], valid: Some(vec![true, false]) };
        a.append(&b).unwrap();
        assert_eq!(a.len(), 4);
        assert!(a.is_valid(0) && a.is_valid(2) && !a.is_valid(3));
        assert_eq!(a.value(3), Value::Null);
    }

    #[test]
    fn display_renders_table() {
        let s = sample().to_string();
        assert!(s.contains("| id | price | name |"), "{s}");
        assert!(s.contains("| 1  | 10.0  | a    |"), "{s}");
    }

    #[test]
    fn byte_size_accounts_strings() {
        let rs = sample();
        assert!(rs.byte_size() > 4 * 16);
    }
}
