//! Scalar values, data types, and schemas.

use std::cmp::Ordering;
use std::fmt;

/// Logical column type.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DataType {
    /// 64-bit signed integer (SQL `BIGINT`).
    Int64,
    /// 64-bit float (SQL `DOUBLE`).
    Float64,
    /// UTF-8 string (SQL `VARCHAR`).
    Utf8,
    /// Boolean (SQL `BOOLEAN`).
    Bool,
}

impl fmt::Display for DataType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            DataType::Int64 => "BIGINT",
            DataType::Float64 => "DOUBLE",
            DataType::Utf8 => "VARCHAR",
            DataType::Bool => "BOOLEAN",
        };
        write!(f, "{s}")
    }
}

/// A dynamically-typed scalar — the unit the scalar-UDF path processes
/// "per row" (§III.A) and the expression evaluator folds over.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// SQL NULL (no type).
    Null,
    /// Integer scalar.
    Int(i64),
    /// Float scalar.
    Float(f64),
    /// String scalar.
    Str(String),
    /// Boolean scalar.
    Bool(bool),
}

impl Value {
    /// The value's type; `None` for NULL.
    pub fn data_type(&self) -> Option<DataType> {
        match self {
            Value::Null => None,
            Value::Int(_) => Some(DataType::Int64),
            Value::Float(_) => Some(DataType::Float64),
            Value::Str(_) => Some(DataType::Utf8),
            Value::Bool(_) => Some(DataType::Bool),
        }
    }

    /// Is this the SQL NULL value?
    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }

    /// Numeric view (ints widen to f64) — SQL arithmetic semantics.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Float(f) => Some(*f),
            _ => None,
        }
    }

    /// Integer view (floats truncate) — SQL cast-to-int semantics.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            Value::Float(f) => Some(*f as i64),
            _ => None,
        }
    }

    /// Borrowed string payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Boolean payload, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// SQL comparison: NULL compares as unknown (None); numerics compare
    /// across Int/Float; mismatched types are an error surfaced as None.
    pub fn sql_cmp(&self, other: &Value) -> Option<Ordering> {
        match (self, other) {
            (Value::Null, _) | (_, Value::Null) => None,
            (Value::Str(a), Value::Str(b)) => Some(a.cmp(b)),
            (Value::Bool(a), Value::Bool(b)) => Some(a.cmp(b)),
            _ => {
                let a = self.as_f64()?;
                let b = other.as_f64()?;
                a.partial_cmp(&b)
            }
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => write!(f, "NULL"),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{x:.1}")
                } else {
                    write!(f, "{x}")
                }
            }
            Value::Str(s) => write!(f, "{s}"),
            Value::Bool(b) => write!(f, "{b}"),
        }
    }
}

/// A named, typed column in a schema.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Field {
    /// Column name (SQL identifiers fold to lowercase at parse time).
    pub name: String,
    /// Column type.
    pub data_type: DataType,
}

impl Field {
    /// Construct a field.
    pub fn new(name: impl Into<String>, data_type: DataType) -> Self {
        Self { name: name.into(), data_type }
    }
}

/// Ordered set of fields. Names are case-insensitive on lookup (SQL
/// identifiers fold to lowercase at parse time).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Schema {
    /// The ordered fields.
    pub fields: Vec<Field>,
}

impl Schema {
    /// Schema from an ordered field list.
    pub fn new(fields: Vec<Field>) -> Self {
        Self { fields }
    }

    /// Schema with no fields.
    pub fn empty() -> Self {
        Self::default()
    }

    /// Number of fields.
    pub fn len(&self) -> usize {
        self.fields.len()
    }

    /// True when the schema has no fields.
    pub fn is_empty(&self) -> bool {
        self.fields.is_empty()
    }

    /// Position of the field named `name` (case-insensitive).
    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.fields
            .iter()
            .position(|f| f.name.eq_ignore_ascii_case(name))
    }

    /// Field by position.
    pub fn field(&self, idx: usize) -> &Field {
        &self.fields[idx]
    }

    /// All field names, in order.
    pub fn names(&self) -> Vec<&str> {
        self.fields.iter().map(|f| f.name.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_type_and_widening() {
        assert_eq!(Value::Int(3).data_type(), Some(DataType::Int64));
        assert_eq!(Value::Null.data_type(), None);
        assert_eq!(Value::Int(3).as_f64(), Some(3.0));
        assert_eq!(Value::Float(2.5).as_i64(), Some(2));
        assert_eq!(Value::Str("x".into()).as_f64(), None);
    }

    #[test]
    fn sql_cmp_semantics() {
        use std::cmp::Ordering::*;
        assert_eq!(Value::Int(1).sql_cmp(&Value::Float(1.5)), Some(Less));
        assert_eq!(Value::Float(2.0).sql_cmp(&Value::Int(2)), Some(Equal));
        assert_eq!(Value::Null.sql_cmp(&Value::Int(1)), None);
        assert_eq!(
            Value::Str("a".into()).sql_cmp(&Value::Str("b".into())),
            Some(Less)
        );
        assert_eq!(Value::Str("a".into()).sql_cmp(&Value::Int(1)), None);
        assert_eq!(Value::Bool(false).sql_cmp(&Value::Bool(true)), Some(Less));
    }

    #[test]
    fn display_formats() {
        assert_eq!(Value::Null.to_string(), "NULL");
        assert_eq!(Value::Int(5).to_string(), "5");
        assert_eq!(Value::Float(2.0).to_string(), "2.0");
        assert_eq!(Value::Float(2.25).to_string(), "2.25");
        assert_eq!(Value::Bool(true).to_string(), "true");
    }

    #[test]
    fn schema_lookup_case_insensitive() {
        let s = Schema::new(vec![
            Field::new("Price", DataType::Float64),
            Field::new("qty", DataType::Int64),
        ]);
        assert_eq!(s.index_of("price"), Some(0));
        assert_eq!(s.index_of("QTY"), Some(1));
        assert_eq!(s.index_of("missing"), None);
        assert_eq!(s.names(), vec!["Price", "qty"]);
    }
}
