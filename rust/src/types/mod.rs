//! Logical types: scalar values, data types, schemas, columnar rowsets,
//! and the column-major wire codec used to ship batches between nodes.

mod rowset;
mod value;
mod wire;

pub use rowset::{Column, RowSet, RowSetBuilder};
pub use value::{DataType, Field, Schema, Value};
pub use wire::WireBatch;
