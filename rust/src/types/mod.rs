//! Logical types: scalar values, data types, schemas.

mod rowset;
mod value;

pub use rowset::{Column, RowSet, RowSetBuilder};
pub use value::{DataType, Field, Schema, Value};
