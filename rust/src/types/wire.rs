//! Compact column-major wire format for shipping rowset batches to
//! interpreter processes (§IV.C) — the gRPC payload stand-in.
//!
//! A [`WireBatch`] is encoded **once per batch** directly from a
//! contiguous row range of a source [`RowSet`] (no intermediate sliced
//! rowset, no per-row `RowSet::row` → `Vec<Value>` round trip), and the
//! receiver decodes it back with typed bulk appends into column buffers.
//! The engine's shuffle (PR 10) ships each partition's gathered
//! representative-key columns as an ordinary batch whose synthetic
//! `__g{i}` field names tag the shipment as partition payload; the
//! destination node is carried by the exchange call, not the frame, so
//! the codec stays position-independent and `wire_len()` keeps costing
//! exactly what travels.
//!
//! ## Byte layout (all integers little-endian)
//!
//! ```text
//! u32 n_cols
//! u32 n_rows
//! per column:
//!   u16  name_len, name bytes (UTF-8 field name)
//!   u8   dtype tag        (0=Int64, 1=Float64, 2=Utf8, 3=Bool)
//!   u8   has_validity     (1 ⇒ a packed validity bitmap follows)
//!   [ceil(n_rows/8) bytes]  validity bitmap, bit i = row i is non-NULL
//!   payload:
//!     Int64/Float64 : n_rows × 8 bytes raw
//!     Bool          : ceil(n_rows/8) bytes, packed bits
//!     Utf8          : n_rows × u32 byte lengths, then the concatenated
//!                     string bytes
//! ```
//!
//! NULL slots ship their (default) payloads so a decode round-trips to a
//! rowset equal to `rs.slice(offset, len)` under `PartialEq`.

use anyhow::{bail, Result};

use super::rowset::{Column, RowSet};
use super::value::{DataType, Field, Schema};

/// One encoded column-major batch (self-describing: schema travels with
/// the payload).
///
/// ```
/// use snowpark::types::{Column, DataType, Field, RowSet, Schema, WireBatch};
/// let rs = RowSet::new(
///     Schema::new(vec![Field::new("x", DataType::Int64)]),
///     vec![Column::from_i64(vec![1, 2, 3])],
/// )
/// .unwrap();
/// let wire = WireBatch::encode(&rs);
/// assert_eq!(wire.decode().unwrap(), rs);
/// ```
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireBatch {
    bytes: Vec<u8>,
    rows: usize,
}

const TAG_I64: u8 = 0;
const TAG_F64: u8 = 1;
const TAG_UTF8: u8 = 2;
const TAG_BOOL: u8 = 3;

fn pack_bits<F: Fn(usize) -> bool>(n: usize, bit: F, out: &mut Vec<u8>) {
    let mut byte = 0u8;
    for i in 0..n {
        if bit(i) {
            byte |= 1 << (i % 8);
        }
        if i % 8 == 7 {
            out.push(byte);
            byte = 0;
        }
    }
    if n % 8 != 0 {
        out.push(byte);
    }
}

fn unpack_bits(bytes: &[u8], n: usize) -> Vec<bool> {
    (0..n).map(|i| bytes[i / 8] & (1 << (i % 8)) != 0).collect()
}

/// Bounds-checked reader over the wire bytes.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!(
                "truncated wire batch: need {} bytes at offset {}, have {}",
                n,
                self.pos,
                self.buf.len()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
}

impl WireBatch {
    /// Encode a whole rowset.
    pub fn encode(rs: &RowSet) -> WireBatch {
        Self::encode_range(rs, 0, rs.num_rows())
    }

    /// Encode rows `[offset, offset + len)` of `rs` straight from its
    /// column buffers — one pass per column, no intermediate rowset.
    pub fn encode_range(rs: &RowSet, offset: usize, len: usize) -> WireBatch {
        let cols: Vec<&Column> = rs.columns.iter().collect();
        Self::encode_columns(&rs.schema.fields, &cols, offset, len)
    }

    /// Encode a row range of loose columns (field metadata supplied
    /// separately) — what the engine's node dispatch uses to ship an
    /// operator's referenced columns without assembling a rowset first.
    pub fn encode_columns(
        fields: &[Field],
        cols: &[&Column],
        offset: usize,
        len: usize,
    ) -> WireBatch {
        assert_eq!(fields.len(), cols.len(), "encode_columns arity");
        assert!(
            cols.iter().all(|c| offset + len <= c.len()),
            "encode_columns out of bounds"
        );
        let mut out: Vec<u8> = Vec::with_capacity(16 + len * cols.len() * 8);
        out.extend_from_slice(&(cols.len() as u32).to_le_bytes());
        out.extend_from_slice(&(len as u32).to_le_bytes());
        for (field, &col) in fields.iter().zip(cols) {
            let name = field.name.as_bytes();
            out.extend_from_slice(&(name.len() as u16).to_le_bytes());
            out.extend_from_slice(name);
            let tag = match col.data_type() {
                DataType::Int64 => TAG_I64,
                DataType::Float64 => TAG_F64,
                DataType::Utf8 => TAG_UTF8,
                DataType::Bool => TAG_BOOL,
            };
            out.push(tag);
            match col.validity() {
                Some(valid) => {
                    out.push(1);
                    pack_bits(len, |i| valid[offset + i], &mut out);
                }
                None => out.push(0),
            }
            match col {
                Column::Int64 { data, .. } => {
                    for &v in &data[offset..offset + len] {
                        out.extend_from_slice(&v.to_le_bytes());
                    }
                }
                Column::Float64 { data, .. } => {
                    for &v in &data[offset..offset + len] {
                        out.extend_from_slice(&v.to_bits().to_le_bytes());
                    }
                }
                Column::Bool { data, .. } => {
                    pack_bits(len, |i| data[offset + i], &mut out);
                }
                Column::Utf8 { data, .. } => {
                    for s in &data[offset..offset + len] {
                        out.extend_from_slice(&(s.len() as u32).to_le_bytes());
                    }
                    for s in &data[offset..offset + len] {
                        out.extend_from_slice(s.as_bytes());
                    }
                }
            }
        }
        WireBatch { bytes: out, rows: len }
    }

    /// Decode back into a rowset with typed bulk appends.
    pub fn decode(&self) -> Result<RowSet> {
        let mut r = Reader { buf: &self.bytes, pos: 0 };
        let n_cols = r.u32()? as usize;
        let n_rows = r.u32()? as usize;
        let mut fields = Vec::with_capacity(n_cols);
        let mut columns = Vec::with_capacity(n_cols);
        for _ in 0..n_cols {
            let name_len = r.u16()? as usize;
            let name = String::from_utf8(r.take(name_len)?.to_vec())
                .map_err(|e| anyhow::anyhow!("bad field name in wire batch: {e}"))?;
            let tag = r.u8()?;
            let has_valid = r.u8()? != 0;
            let valid = if has_valid {
                let bm = r.take(n_rows.div_ceil(8))?;
                Some(unpack_bits(bm, n_rows))
            } else {
                None
            };
            let (dt, col) = match tag {
                TAG_I64 => {
                    let raw = r.take(n_rows * 8)?;
                    let data: Vec<i64> = raw
                        .chunks_exact(8)
                        .map(|c| i64::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    (DataType::Int64, Column::Int64 { data, valid })
                }
                TAG_F64 => {
                    let raw = r.take(n_rows * 8)?;
                    let data: Vec<f64> = raw
                        .chunks_exact(8)
                        .map(|c| f64::from_bits(u64::from_le_bytes(c.try_into().unwrap())))
                        .collect();
                    (DataType::Float64, Column::Float64 { data, valid })
                }
                TAG_BOOL => {
                    let bm = r.take(n_rows.div_ceil(8))?;
                    (DataType::Bool, Column::Bool { data: unpack_bits(bm, n_rows), valid })
                }
                TAG_UTF8 => {
                    let raw = r.take(n_rows * 4)?;
                    let lens: Vec<usize> = raw
                        .chunks_exact(4)
                        .map(|c| u32::from_le_bytes(c.try_into().unwrap()) as usize)
                        .collect();
                    let mut data = Vec::with_capacity(n_rows);
                    for len in lens {
                        let s = String::from_utf8(r.take(len)?.to_vec())
                            .map_err(|e| anyhow::anyhow!("bad UTF-8 in wire batch: {e}"))?;
                        data.push(s);
                    }
                    (DataType::Utf8, Column::Utf8 { data, valid })
                }
                other => bail!("unknown wire column tag {other}"),
            };
            fields.push(Field::new(name, dt));
            columns.push(col);
        }
        RowSet::new(Schema::new(fields), columns)
    }

    /// The exact byte size [`WireBatch::encode_columns`] would produce
    /// for this row range, computed without building the buffer — pure
    /// arithmetic over the byte layout above. The engine's fragment
    /// statistics use it to price what per-operator dispatch *would*
    /// have shipped.
    pub fn encoded_size(fields: &[Field], cols: &[&Column], offset: usize, len: usize) -> usize {
        assert_eq!(fields.len(), cols.len(), "encoded_size arity");
        let mut size = 8; // u32 n_cols + u32 n_rows
        for (field, &col) in fields.iter().zip(cols) {
            size += 2 + field.name.len() + 1 + 1; // name_len, name, tag, has_validity
            if col.validity().is_some() {
                size += len.div_ceil(8);
            }
            size += match col {
                Column::Int64 { .. } | Column::Float64 { .. } => len * 8,
                Column::Bool { .. } => len.div_ceil(8),
                Column::Utf8 { data, .. } => {
                    len * 4 + data[offset..offset + len].iter().map(String::len).sum::<usize>()
                }
            };
        }
        size
    }

    /// Encoded size in bytes — what the transport-cost model charges.
    pub fn wire_len(&self) -> usize {
        self.bytes.len()
    }

    /// The raw encoded bytes, for embedding a batch in an outer envelope
    /// (the serving protocol's `Result` frame ships these verbatim).
    pub fn as_bytes(&self) -> &[u8] {
        &self.bytes
    }

    /// Reconstruct a batch from raw encoded bytes (the receive side of
    /// [`WireBatch::as_bytes`]). Only the 8-byte header is validated
    /// here — enough to recover the row count; [`WireBatch::decode`]
    /// bounds-checks the full payload, so a corrupted body surfaces as a
    /// clean decode error, never a panic.
    pub fn from_bytes(bytes: Vec<u8>) -> Result<WireBatch> {
        if bytes.len() < 8 {
            bail!("wire batch too short: {} bytes, need at least 8", bytes.len());
        }
        let rows = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]) as usize;
        Ok(WireBatch { bytes, rows })
    }

    /// Number of rows in the batch (without decoding).
    pub fn num_rows(&self) -> usize {
        self.rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::Value;

    fn sample() -> RowSet {
        RowSet::new(
            Schema::new(vec![
                Field::new("i", DataType::Int64),
                Field::new("f", DataType::Float64),
                Field::new("s", DataType::Utf8),
                Field::new("b", DataType::Bool),
            ]),
            vec![
                Column::Int64 {
                    data: vec![1, 0, -3, 4, 5, 6, 7, 8, 9],
                    valid: Some(vec![true, false, true, true, true, true, true, true, true]),
                },
                Column::from_f64(vec![0.5, -0.0, 2.0, 3.5, 4.0, 5.5, 6.0, 7.5, f64::MAX]),
                Column::Utf8 {
                    data: (0..9).map(|i| format!("s{i}")).collect(),
                    valid: Some(vec![true; 8].into_iter().chain([false]).collect()),
                },
                Column::from_bools(vec![
                    true, false, true, true, false, false, true, false, true,
                ]),
            ],
        )
        .unwrap()
    }

    #[test]
    fn round_trip_whole_rowset() {
        let rs = sample();
        let decoded = WireBatch::encode(&rs).decode().unwrap();
        assert_eq!(decoded, rs);
    }

    #[test]
    fn round_trip_ranges() {
        let rs = sample();
        // 9 rows exercises the partial-byte bitmap tail.
        for (off, len) in [(0, 9), (0, 8), (1, 8), (3, 3), (8, 1), (4, 0)] {
            let decoded = WireBatch::encode_range(&rs, off, len).decode().unwrap();
            assert_eq!(decoded, rs.slice(off, len), "range ({off}, {len})");
        }
    }

    #[test]
    fn negative_zero_and_nulls_survive() {
        let rs = sample();
        let decoded = WireBatch::encode(&rs).decode().unwrap();
        // -0.0 keeps its sign bit through the bit-level f64 encoding.
        let f = decoded.column(1).f64_data().unwrap();
        assert!(f[1] == 0.0 && f[1].is_sign_negative());
        assert_eq!(decoded.column(0).value(1), Value::Null);
        assert_eq!(decoded.column(2).value(8), Value::Null);
    }

    #[test]
    fn empty_rowset_round_trips() {
        let rs = RowSet::new(
            Schema::new(vec![Field::new("x", DataType::Int64)]),
            vec![Column::from_i64(vec![])],
        )
        .unwrap();
        let w = WireBatch::encode(&rs);
        assert_eq!(w.num_rows(), 0);
        assert_eq!(w.decode().unwrap(), rs);
    }

    #[test]
    fn truncated_input_is_an_error_not_a_panic() {
        let rs = sample();
        let w = WireBatch::encode(&rs);
        for cut in [0, 4, 9, w.wire_len() / 2, w.wire_len() - 1] {
            let t = WireBatch { bytes: w.bytes[..cut].to_vec(), rows: w.rows };
            assert!(t.decode().is_err(), "cut at {cut} should fail");
        }
    }

    #[test]
    fn encoded_size_matches_encoder() {
        let rs = sample();
        let cols: Vec<&Column> = rs.columns.iter().collect();
        for (off, len) in [(0, 9), (0, 8), (1, 8), (3, 3), (8, 1), (4, 0)] {
            let predicted = WireBatch::encoded_size(&rs.schema.fields, &cols, off, len);
            let actual = WireBatch::encode_columns(&rs.schema.fields, &cols, off, len);
            assert_eq!(predicted, actual.wire_len(), "range ({off}, {len})");
        }
    }

    #[test]
    fn raw_bytes_round_trip() {
        let rs = sample();
        let w = WireBatch::encode(&rs);
        let rebuilt = WireBatch::from_bytes(w.as_bytes().to_vec()).unwrap();
        assert_eq!(rebuilt, w);
        assert_eq!(rebuilt.num_rows(), w.num_rows());
        assert_eq!(rebuilt.decode().unwrap(), rs);
        // Headerless fragments are rejected up front.
        assert!(WireBatch::from_bytes(vec![1, 2, 3]).is_err());
        // A corrupted body defers to decode's bounds checks.
        let mut bad = w.as_bytes().to_vec();
        bad.truncate(bad.len() - 1);
        assert!(WireBatch::from_bytes(bad).unwrap().decode().is_err());
    }

    #[test]
    fn wire_len_is_compact() {
        let rs = sample();
        let w = WireBatch::encode(&rs);
        // Column-major fixed-width payloads: well under a Value-per-cell
        // representation, and within 2x of the raw column bytes.
        assert!(w.wire_len() as u64 <= rs.byte_size() * 2 + 128);
    }
}
