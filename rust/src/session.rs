//! The user-facing session: the composition root that binds the catalog,
//! the UDF registry, the interpreter pool, the exchange policy, and the
//! (optional) XLA runtime into one handle — what `snowpark.Session` is to
//! the Python client.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{anyhow, Result};

use crate::dataframe::DataFrame;
use crate::engine::exchange::{run_udf_exchange, ExchangeConfig, ExchangeMode, ExchangeReport};
use crate::engine::{Catalog, ExecContext};
use crate::runtime::XlaService;
use crate::types::{Column, DataType, Field, RowSet, Schema};
use crate::udf::{ScalarFn, UdfRegistry, UdfStatsStore, VectorizedFn};
use crate::warehouse::{InterpreterPool, PoolConfig};

/// Builder for [`Session`].
pub struct SessionBuilder {
    pool: Option<PoolConfig>,
    exchange: ExchangeConfig,
    artifacts_dir: Option<std::path::PathBuf>,
    parallelism: Option<usize>,
    nodes: Option<usize>,
}

impl SessionBuilder {
    pub fn pool(mut self, config: PoolConfig) -> Self {
        self.pool = Some(config);
        self
    }

    pub fn exchange(mut self, config: ExchangeConfig) -> Self {
        self.exchange = config;
        self
    }

    /// Pin the engine's intra-query (morsel) parallelism per node.
    /// Without this, sessions with a pool use the warehouse shape (one
    /// worker per interpreter process on a node, i.e. `procs_per_node`)
    /// and pool-less sessions use
    /// [`crate::engine::default_parallelism`].
    pub fn parallelism(mut self, threads: usize) -> Self {
        self.parallelism = Some(threads.max(1));
        self
    }

    /// Pin the number of warehouse nodes query morsels spread across
    /// (`snowparkd run-sql --nodes N`). Without this, sessions with a
    /// pool use the pool's node count and pool-less sessions use
    /// [`crate::engine::default_nodes`].
    pub fn nodes(mut self, nodes: usize) -> Self {
        self.nodes = Some(nodes.max(1));
        self
    }

    /// Attach AOT artifacts (enables the XLA-backed vectorized UDFs).
    pub fn artifacts(mut self, dir: impl Into<std::path::PathBuf>) -> Self {
        self.artifacts_dir = Some(dir.into());
        self
    }

    pub fn build(self) -> Result<Arc<Session>> {
        let catalog = Arc::new(Catalog::new());
        let registry = Arc::new(RwLock::new(UdfRegistry::new()));
        let stats = Arc::new(UdfStatsStore::new());
        let runtime = match &self.artifacts_dir {
            Some(dir) if crate::runtime::XlaRuntime::available(dir) => {
                Some(Arc::new(XlaService::start(dir)?))
            }
            Some(dir) => {
                return Err(anyhow!(
                    "no artifacts at {} — run `make artifacts` first",
                    dir.display()
                ))
            }
            None => None,
        };
        let session = Arc::new(Session {
            catalog,
            registry,
            stats,
            pool_config: self.pool,
            pool: Mutex::new(None),
            exchange: self.exchange,
            runtime,
            parallelism: self.parallelism,
            nodes: self.nodes,
            partitioned: RwLock::new(HashMap::new()),
        });
        if let Some(rt) = &session.runtime {
            crate::runtime::kernels::register_xla_udfs(&session, rt.clone())?;
        }
        Ok(session)
    }
}

/// A Snowpark session.
pub struct Session {
    catalog: Arc<Catalog>,
    registry: Arc<RwLock<UdfRegistry>>,
    stats: Arc<UdfStatsStore>,
    pool_config: Option<PoolConfig>,
    /// Lazily-spawned interpreter pool (threads are only created when a
    /// distributed UDF query actually runs).
    pool: Mutex<Option<Arc<InterpreterPool>>>,
    exchange: ExchangeConfig,
    runtime: Option<Arc<XlaService>>,
    /// Explicit intra-query parallelism override (None = derive from the
    /// warehouse shape, else the engine default).
    parallelism: Option<usize>,
    /// Explicit node-count override for query morsel dispatch (None =
    /// derive from the pool shape, else the engine default).
    nodes: Option<usize>,
    /// Partitioned tables: name → per-node rowsets (the source rowset
    /// operator's placement for §IV.C).
    partitioned: RwLock<HashMap<String, Vec<RowSet>>>,
}

impl Session {
    pub fn builder() -> SessionBuilder {
        SessionBuilder {
            pool: None,
            exchange: ExchangeConfig::default(),
            artifacts_dir: None,
            parallelism: None,
            nodes: None,
        }
    }

    pub fn catalog(&self) -> &Catalog {
        &self.catalog
    }

    pub fn runtime(&self) -> Option<&Arc<XlaService>> {
        self.runtime.as_ref()
    }

    pub fn udf_stats(&self) -> &Arc<UdfStatsStore> {
        &self.stats
    }

    pub fn exchange_config(&self) -> ExchangeConfig {
        self.exchange
    }

    /// Register a scalar UDF (row-at-a-time, §III.A).
    pub fn register_scalar_udf(&self, name: &str, return_type: DataType, body: ScalarFn) {
        self.registry
            .write()
            .unwrap()
            .register_scalar(name, return_type, body);
    }

    /// Register a vectorized UDF (batch-at-a-time, §III.A "vectorized
    /// interfaces for Python UDFs").
    pub fn register_vectorized_udf(&self, name: &str, return_type: DataType, body: VectorizedFn) {
        self.registry
            .write()
            .unwrap()
            .register_vectorized(name, return_type, body);
    }

    /// Declare the packages a UDF imports (drives §IV.A init costs).
    pub fn set_udf_packages(&self, name: &str, packages: &[&str]) {
        self.registry.write().unwrap().set_packages(name, packages);
    }

    /// Set the static per-row cost estimate for a scalar UDF (seed for
    /// the §IV.C threshold decision before history exists).
    pub fn set_udf_row_cost(&self, name: &str, ns: u64) {
        self.registry.write().unwrap().set_row_cost(name, ns);
    }

    /// Snapshot of the registry (cheap clone of definitions).
    pub fn udfs(&self) -> UdfRegistry {
        self.registry.read().unwrap().clone()
    }

    /// Register a table partitioned across warehouse nodes: partition `i`
    /// lives on node `i % nodes`. The merged view is also queryable.
    pub fn register_partitioned(&self, name: &str, partitions: Vec<RowSet>) -> Result<()> {
        let mut merged = partitions
            .first()
            .map(|p| RowSet::empty(p.schema.clone()))
            .ok_or_else(|| anyhow!("no partitions"))?;
        for p in &partitions {
            merged.append(p)?;
        }
        self.catalog.register(name, merged);
        self.partitioned
            .write()
            .unwrap()
            .insert(name.to_ascii_lowercase(), partitions);
        Ok(())
    }

    pub fn partitions_of(&self, name: &str) -> Option<Vec<RowSet>> {
        self.partitioned
            .read()
            .unwrap()
            .get(&name.to_ascii_lowercase())
            .cloned()
    }

    /// The morsel parallelism queries run with: the explicit builder
    /// override, else the warehouse shape (`procs_per_node` — the SQL
    /// operators of one query run on one node's interpreter-process
    /// budget), else the engine default (env var / host cores).
    pub fn query_parallelism(&self) -> usize {
        self.parallelism
            .or_else(|| self.pool_config.map(|c| c.distributed_query_shape().1))
            .unwrap_or_else(crate::engine::default_parallelism)
            .max(1)
    }

    /// The warehouse-node count query morsels spread across: the
    /// explicit builder override (`snowparkd run-sql --nodes N`), else
    /// the pool shape (`PoolConfig::distributed_query_shape` — the same
    /// nodes the UDF exchange deals batches to), else the engine
    /// default (`SNOWPARK_NODES`, else 1).
    pub fn query_nodes(&self) -> usize {
        self.nodes
            .or_else(|| self.pool_config.map(|c| c.distributed_query_shape().0))
            .unwrap_or_else(crate::engine::default_nodes)
            .max(1)
    }

    fn exec_context(&self) -> ExecContext {
        ExecContext {
            catalog: self.catalog.clone(),
            udfs: Arc::new(self.udfs()),
            udf_stats: self.stats.clone(),
            vectorized: true,
            parallelism: self.query_parallelism(),
            nodes: self.query_nodes(),
            steal: true,
            transport: self.pool_config.map(|c| c.transport).unwrap_or_default(),
            tally: Arc::new(crate::engine::ExecTally::default()),
        }
    }

    /// Run a SQL statement on the leader.
    pub fn sql(&self, text: &str) -> Result<RowSet> {
        let ctx = self.exec_context();
        crate::engine::run_sql(text, &ctx)
    }

    /// Run a SQL statement, also returning per-operator rows and timings.
    pub fn sql_with_stats(&self, text: &str) -> Result<(RowSet, crate::engine::QueryStats)> {
        let ctx = self.exec_context();
        crate::engine::run_sql_with_stats(text, &ctx)
    }

    /// Open a DataFrame on a table.
    pub fn table(self: &Arc<Self>, name: &str) -> DataFrame {
        DataFrame::from_table(self.clone(), name)
    }

    /// Open a DataFrame over arbitrary SQL.
    pub fn sql_frame(self: &Arc<Self>, sql: &str) -> DataFrame {
        DataFrame::from_sql(self.clone(), sql)
    }

    /// Get (spawning on first use) the interpreter pool.
    pub fn pool(&self) -> Result<Arc<InterpreterPool>> {
        let mut guard = self.pool.lock().unwrap();
        if guard.is_none() {
            let cfg = self
                .pool_config
                .ok_or_else(|| anyhow!("session built without a pool configuration"))?;
            *guard = Some(Arc::new(InterpreterPool::spawn(
                cfg,
                Arc::new(self.udfs()),
                self.stats.clone(),
            )));
        }
        Ok(guard.as_ref().unwrap().clone())
    }

    /// Drop the pool (it respawns with fresh registry state on next use).
    pub fn reset_pool(&self) {
        *self.pool.lock().unwrap() = None;
    }

    /// Distributed UDF projection over a partitioned table (§IV.C): apply
    /// `udf(input_col)` to every row of `table`, routing batches through
    /// the interpreter pool under `mode`. Returns the output column
    /// (ordered: partition 0's rows first) and the exchange report.
    pub fn run_distributed_udf(
        &self,
        table: &str,
        udf: &str,
        input_cols: &[&str],
        mode: ExchangeMode,
    ) -> Result<(Column, ExchangeReport)> {
        let partitions = self
            .partitions_of(table)
            .ok_or_else(|| anyhow!("table {table:?} is not partitioned"))?;
        // Project the UDF's argument columns per partition.
        let projected: Vec<RowSet> = partitions
            .iter()
            .map(|p| {
                let mut fields = Vec::new();
                let mut cols = Vec::new();
                for c in input_cols {
                    let col = p
                        .column_by_name(c)
                        .ok_or_else(|| anyhow!("no column {c:?} in {table:?}"))?
                        .clone();
                    fields.push(Field::new(*c, col.data_type()));
                    cols.push(col);
                }
                RowSet::new(Schema::new(fields), cols)
            })
            .collect::<Result<_>>()?;
        let pool = self.pool()?;
        let registry = self.udfs();
        let cfg = ExchangeConfig { mode, ..self.exchange };
        let (columns, report) = run_udf_exchange(&projected, udf, &pool, &registry, cfg)?;
        // Stitch partition outputs into one column (partition order) by
        // concatenating the typed columns directly — the exchange already
        // typed every partition from the registry's declared return type,
        // so no per-cell `Value` round trips and no dtype re-inference.
        let mut iter = columns.into_iter();
        let mut out = iter
            .next()
            .ok_or_else(|| anyhow!("exchange returned no partitions"))?;
        for c in iter {
            out.append(&c)?;
        }
        Ok((out, report))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::Value;

    fn parts() -> Vec<RowSet> {
        (0..2)
            .map(|p| {
                RowSet::new(
                    Schema::new(vec![Field::new("x", DataType::Float64)]),
                    vec![Column::from_f64(
                        (0..10).map(|i| (p * 100 + i) as f64).collect(),
                    )],
                )
                .unwrap()
            })
            .collect()
    }

    #[test]
    fn partitioned_table_also_queryable_merged() {
        let s = Session::builder().build().unwrap();
        s.register_partitioned("events", parts()).unwrap();
        let rs = s.sql("SELECT COUNT(*) AS n FROM events").unwrap();
        assert_eq!(rs.row(0)[0], Value::Int(20));
        assert_eq!(s.partitions_of("events").unwrap().len(), 2);
        assert!(s.partitions_of("missing").is_none());
    }

    #[test]
    fn distributed_udf_round_trip() {
        let s = Session::builder()
            .pool(PoolConfig { nodes: 2, procs_per_node: 2, ..Default::default() })
            .build()
            .unwrap();
        s.register_partitioned("events", parts()).unwrap();
        s.register_scalar_udf(
            "plus1",
            DataType::Float64,
            Arc::new(|args| Ok(Value::Float(args[0].as_f64().unwrap_or(0.0) + 1.0))),
        );
        for mode in [ExchangeMode::Local, ExchangeMode::RoundRobin] {
            let (col, report) = s
                .run_distributed_udf("events", "plus1", &["x"], mode)
                .unwrap();
            assert_eq!(col.len(), 20);
            assert_eq!(col.value(0), Value::Float(1.0));
            assert_eq!(col.value(10), Value::Float(101.0));
            assert_eq!(report.rows, 20);
        }
    }

    #[test]
    fn pool_requires_config() {
        let s = Session::builder().build().unwrap();
        assert!(s.pool().is_err());
    }

    #[test]
    fn parallelism_derived_from_warehouse_shape() {
        // With a pool: one morsel worker per interpreter process on a
        // node, and morsels spread across the pool's nodes.
        let s = Session::builder()
            .pool(PoolConfig { nodes: 2, procs_per_node: 3, ..Default::default() })
            .build()
            .unwrap();
        assert_eq!(s.query_parallelism(), 3);
        assert_eq!(s.query_nodes(), 2);
        // Explicit overrides win.
        let s = Session::builder().parallelism(7).nodes(3).build().unwrap();
        assert_eq!(s.query_parallelism(), 7);
        assert_eq!(s.query_nodes(), 3);
        // Pool-less sessions fall back to the engine defaults.
        let s = Session::builder().build().unwrap();
        assert!(s.query_parallelism() >= 1);
        assert!(s.query_nodes() >= 1);
    }

    #[test]
    fn sql_runs_across_pool_nodes() {
        // A session whose pool spans nodes runs its SQL through the node
        // dispatch path; outputs must match a single-node session.
        let rows = 20_000usize;
        let xs: Vec<f64> = (0..rows).map(|i| (i % 997) as f64).collect();
        let make = |nodes: usize| {
            let s = Session::builder()
                .pool(PoolConfig { nodes, procs_per_node: 2, ..Default::default() })
                .build()
                .unwrap();
            s.catalog().register(
                "t",
                RowSet::new(
                    Schema::new(vec![Field::new("x", DataType::Float64)]),
                    vec![Column::from_f64(xs.clone())],
                )
                .unwrap(),
            );
            s
        };
        let q = "SELECT x, COUNT(*) AS n FROM t GROUP BY x ORDER BY n DESC, x LIMIT 7";
        let single = make(1).sql(q).unwrap();
        let multi = make(3).sql(q).unwrap();
        assert_eq!(single, multi);
    }

    #[test]
    fn distributed_udf_keeps_declared_dtype() {
        // A UDF that returns NULL for every row of the first partition:
        // the output column must still carry the declared Float64 dtype
        // (not a Float64-by-fallback that breaks for other decls), and
        // all-Int UDFs must come back Int64.
        let s = Session::builder()
            .pool(PoolConfig { nodes: 2, procs_per_node: 2, ..Default::default() })
            .build()
            .unwrap();
        s.register_partitioned("events", parts()).unwrap();
        s.register_scalar_udf(
            "to_int",
            DataType::Int64,
            Arc::new(|args| Ok(Value::Int(args[0].as_f64().unwrap_or(0.0) as i64))),
        );
        let (col, _) = s
            .run_distributed_udf("events", "to_int", &["x"], ExchangeMode::Local)
            .unwrap();
        assert_eq!(col.data_type(), DataType::Int64);
        assert_eq!(col.len(), 20);
        s.register_scalar_udf("all_null", DataType::Float64, Arc::new(|_| Ok(Value::Null)));
        let (col, _) = s
            .run_distributed_udf("events", "all_null", &["x"], ExchangeMode::Local)
            .unwrap();
        assert_eq!(col.data_type(), DataType::Float64);
        assert!((0..col.len()).all(|i| !col.is_valid(i)));
        // Declared Int64 but emits floats: widened (like the inline
        // expression path), never silently truncated.
        s.register_scalar_udf(
            "halvef",
            DataType::Int64,
            Arc::new(|args| Ok(Value::Float(args[0].as_f64().unwrap_or(0.0) / 2.0))),
        );
        let (col, _) = s
            .run_distributed_udf("events", "halvef", &["x"], ExchangeMode::Local)
            .unwrap();
        assert_eq!(col.data_type(), DataType::Float64);
        assert_eq!(col.value(1), Value::Float(0.5));
    }
}
