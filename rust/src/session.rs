//! The user-facing session: the composition root that binds the catalog,
//! the UDF registry, the interpreter pool, the exchange policy, and the
//! (optional) XLA runtime into one handle — what `snowpark.Session` is to
//! the Python client.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::dataframe::DataFrame;
use crate::engine::exchange::{run_udf_exchange, ExchangeConfig, ExchangeMode, ExchangeReport};
use crate::engine::fault::{CancelToken, FaultPlan, FaultScope};
use crate::engine::{Catalog, EngineConfig, ExecContext};
use crate::runtime::XlaService;
use crate::scheduler::{ShapePolicy, StatsFramework};
use crate::types::{Column, DataType, Field, RowSet, Schema};
use crate::udf::{ScalarFn, UdfRegistry, UdfStatsStore, VectorizedFn};
use crate::warehouse::{InterpreterPool, PoolConfig};

/// Builder for [`Session`].
pub struct SessionBuilder {
    pool: Option<PoolConfig>,
    exchange: ExchangeConfig,
    artifacts_dir: Option<std::path::PathBuf>,
    engine: Option<EngineConfig>,
    parallelism: Option<usize>,
    nodes: Option<usize>,
    adaptive_shape: Option<bool>,
    query_timeout: Option<Duration>,
    fault_plan: Option<FaultPlan>,
    catalog: Option<Arc<Catalog>>,
}

impl SessionBuilder {
    pub fn pool(mut self, config: PoolConfig) -> Self {
        self.pool = Some(config);
        self
    }

    pub fn exchange(mut self, config: ExchangeConfig) -> Self {
        self.exchange = config;
        self
    }

    /// Supply a pre-resolved [`EngineConfig`] as the base layer (the CLI
    /// resolves `EngineConfig::from_env()` once, applies its flags on
    /// top, and hands the result here). Without this the builder
    /// resolves the environment itself. The individual setters below
    /// ([`SessionBuilder::parallelism`], [`SessionBuilder::nodes`],
    /// [`SessionBuilder::adaptive_shape`],
    /// [`SessionBuilder::fault_plan`]) layer over whichever base is in
    /// effect — env < builder < CLI, resolved exactly once at
    /// [`SessionBuilder::build`].
    pub fn engine_config(mut self, config: EngineConfig) -> Self {
        self.engine = Some(config);
        self
    }

    /// Pin the engine's intra-query (morsel) parallelism per node.
    /// Without this, sessions with a pool use the warehouse shape (one
    /// worker per interpreter process on a node, i.e. `procs_per_node`)
    /// and pool-less sessions use
    /// [`crate::engine::default_parallelism`].
    pub fn parallelism(mut self, threads: usize) -> Self {
        self.parallelism = Some(threads.max(1));
        self
    }

    /// Pin the number of warehouse nodes query morsels spread across
    /// (`snowparkd run-sql --nodes N`). Without this, sessions with a
    /// pool use the pool's node count and pool-less sessions use
    /// [`crate::engine::default_nodes`].
    pub fn nodes(mut self, nodes: usize) -> Self {
        self.nodes = Some(nodes.max(1));
        self
    }

    /// Enable or disable the §IV.C adaptive query-shape policy
    /// (`snowparkd run-sql --adaptive-shape`). When on, each query's
    /// shape comes from [`ShapePolicy`] consulting the session's
    /// recorded per-query node-balance history (the node fan-out is
    /// the adaptive dimension); explicit
    /// [`SessionBuilder::nodes`] / [`SessionBuilder::parallelism`]
    /// overrides pin their dimension. Default: on for sessions with a
    /// pool (a real warehouse to adapt), off otherwise; the
    /// `SNOWPARK_ADAPTIVE_SHAPE` env var (`1`/`0`) overrides the
    /// default.
    pub fn adaptive_shape(mut self, on: bool) -> Self {
        self.adaptive_shape = Some(on);
        self
    }

    /// Bound every statement's wall time (`snowparkd run-sql --timeout
    /// MS`): a query that outlives the deadline returns a clean
    /// [`crate::engine::fault::DeadlineExceeded`] error instead of
    /// hanging — cooperative cancellation checked at operator entry and
    /// morsel boundaries, with every worker joined on the way out.
    pub fn query_timeout(mut self, timeout: Duration) -> Self {
        self.query_timeout = Some(timeout);
        self
    }

    /// Inject deterministic faults into every statement's node dispatch
    /// (`snowparkd run-sql --fault-plan SPEC`; see
    /// [`FaultPlan::parse`] for the spec grammar). Each statement gets a
    /// fresh [`FaultScope`], so count-based triggers re-arm per query.
    /// Without this, the `SNOWPARK_FAULT_PLAN` env var applies.
    pub fn fault_plan(mut self, plan: FaultPlan) -> Self {
        self.fault_plan = Some(plan);
        self
    }

    /// Attach AOT artifacts (enables the XLA-backed vectorized UDFs).
    pub fn artifacts(mut self, dir: impl Into<std::path::PathBuf>) -> Self {
        self.artifacts_dir = Some(dir.into());
        self
    }

    /// Share an existing catalog instead of creating a fresh one — how
    /// the serving layer's per-tenant sessions all see one registered
    /// dataset without cloning it per tenant. Tables registered through
    /// any sharing session are visible to all of them.
    pub fn shared_catalog(mut self, catalog: Arc<Catalog>) -> Self {
        self.catalog = Some(catalog);
        self
    }

    pub fn build(self) -> Result<Arc<Session>> {
        let catalog = self.catalog.unwrap_or_default();
        let registry = Arc::new(RwLock::new(UdfRegistry::new()));
        let stats = Arc::new(UdfStatsStore::new());
        let runtime = match &self.artifacts_dir {
            Some(dir) if crate::runtime::XlaRuntime::available(dir) => {
                Some(Arc::new(XlaService::start(dir)?))
            }
            Some(dir) => {
                return Err(anyhow!(
                    "no artifacts at {} — run `make artifacts` first",
                    dir.display()
                ))
            }
            None => None,
        };
        // Resolve the engine configuration exactly once: the supplied
        // base (or the environment), then the builder's explicit
        // setters on top.
        let mut engine = self.engine.unwrap_or_else(EngineConfig::from_env);
        if let Some(p) = self.parallelism {
            engine.parallelism = Some(p);
        }
        if let Some(n) = self.nodes {
            engine.nodes = Some(n);
        }
        if let Some(a) = self.adaptive_shape {
            engine.adaptive_shape = Some(a);
        }
        if let Some(fp) = self.fault_plan {
            engine.fault_plan = Some(fp);
        }
        let adaptive = engine.adaptive_shape.unwrap_or(self.pool.is_some());
        let session = Arc::new(Session {
            catalog,
            registry,
            stats,
            pool_config: self.pool,
            pool: Mutex::new(None),
            exchange: self.exchange,
            runtime,
            engine,
            adaptive,
            shape_policy: ShapePolicy::default(),
            balance_stats: StatsFramework::new(32),
            partitioned: RwLock::new(HashMap::new()),
            query_timeout: self.query_timeout,
            deadline_exceeded: AtomicU64::new(0),
        });
        if let Some(rt) = &session.runtime {
            crate::runtime::kernels::register_xla_udfs(&session, rt.clone())?;
        }
        Ok(session)
    }
}

/// A Snowpark session.
pub struct Session {
    catalog: Arc<Catalog>,
    registry: Arc<RwLock<UdfRegistry>>,
    stats: Arc<UdfStatsStore>,
    pool_config: Option<PoolConfig>,
    /// Lazily-spawned interpreter pool (threads are only created when a
    /// distributed UDF query actually runs).
    pool: Mutex<Option<Arc<InterpreterPool>>>,
    exchange: ExchangeConfig,
    runtime: Option<Arc<XlaService>>,
    /// The resolved engine configuration (env < builder < CLI, resolved
    /// once at build time).
    engine: EngineConfig,
    /// Adapt each query's `(nodes, parallelism)` from its recorded
    /// node-balance history (§IV.C threshold rule). Resolved from
    /// [`EngineConfig::adaptive_shape`] (default: on with a pool).
    adaptive: bool,
    /// The adaptive policy (lookback / skew threshold / busy floor).
    shape_policy: ShapePolicy,
    /// Per-query node-balance history (keyed by SQL text), fed from
    /// `QueryStats::per_node_busy_ns` after every execution.
    balance_stats: StatsFramework,
    /// Partitioned tables: name → per-node rowsets (the source rowset
    /// operator's placement for §IV.C).
    partitioned: RwLock<HashMap<String, Vec<RowSet>>>,
    /// Per-statement wall-time bound (None = unbounded).
    query_timeout: Option<Duration>,
    /// Statements this session aborted with `DeadlineExceeded`.
    deadline_exceeded: AtomicU64,
}

impl Session {
    pub fn builder() -> SessionBuilder {
        SessionBuilder {
            pool: None,
            exchange: ExchangeConfig::default(),
            artifacts_dir: None,
            engine: None,
            parallelism: None,
            nodes: None,
            adaptive_shape: None,
            query_timeout: None,
            fault_plan: None,
            catalog: None,
        }
    }

    pub fn catalog(&self) -> &Catalog {
        &self.catalog
    }

    pub fn runtime(&self) -> Option<&Arc<XlaService>> {
        self.runtime.as_ref()
    }

    pub fn udf_stats(&self) -> &Arc<UdfStatsStore> {
        &self.stats
    }

    pub fn exchange_config(&self) -> ExchangeConfig {
        self.exchange
    }

    /// Register a scalar UDF (row-at-a-time, §III.A).
    pub fn register_scalar_udf(&self, name: &str, return_type: DataType, body: ScalarFn) {
        self.registry
            .write()
            .unwrap()
            .register_scalar(name, return_type, body);
    }

    /// Register a vectorized UDF (batch-at-a-time, §III.A "vectorized
    /// interfaces for Python UDFs").
    pub fn register_vectorized_udf(&self, name: &str, return_type: DataType, body: VectorizedFn) {
        self.registry
            .write()
            .unwrap()
            .register_vectorized(name, return_type, body);
    }

    /// Declare the packages a UDF imports (drives §IV.A init costs).
    pub fn set_udf_packages(&self, name: &str, packages: &[&str]) {
        self.registry.write().unwrap().set_packages(name, packages);
    }

    /// Set the static per-row cost estimate for a scalar UDF (seed for
    /// the §IV.C threshold decision before history exists).
    pub fn set_udf_row_cost(&self, name: &str, ns: u64) {
        self.registry.write().unwrap().set_row_cost(name, ns);
    }

    /// Snapshot of the registry (cheap clone of definitions).
    pub fn udfs(&self) -> UdfRegistry {
        self.registry.read().unwrap().clone()
    }

    /// Register a table partitioned across warehouse nodes: partition `i`
    /// lives on node `i % nodes`. The merged view is also queryable.
    pub fn register_partitioned(&self, name: &str, partitions: Vec<RowSet>) -> Result<()> {
        let mut merged = partitions
            .first()
            .map(|p| RowSet::empty(p.schema.clone()))
            .ok_or_else(|| anyhow!("no partitions"))?;
        for p in &partitions {
            merged.append(p)?;
        }
        self.catalog.register(name, merged);
        self.partitioned
            .write()
            .unwrap()
            .insert(name.to_ascii_lowercase(), partitions);
        Ok(())
    }

    pub fn partitions_of(&self, name: &str) -> Option<Vec<RowSet>> {
        self.partitioned
            .read()
            .unwrap()
            .get(&name.to_ascii_lowercase())
            .cloned()
    }

    /// The session's resolved [`EngineConfig`] (env < builder < CLI,
    /// resolved once at build; its `Display` backs the `--stats`
    /// header).
    pub fn engine_config(&self) -> &EngineConfig {
        &self.engine
    }

    /// The morsel parallelism queries run with: the resolved
    /// [`EngineConfig::parallelism`] (builder/CLI override or the
    /// `SNOWPARK_PARALLELISM` env var), else the warehouse shape
    /// (`procs_per_node` — the SQL operators of one query run on one
    /// node's interpreter-process budget), else the host core count.
    pub fn query_parallelism(&self) -> usize {
        self.engine
            .parallelism
            .or_else(|| self.pool_config.map(|c| c.distributed_query_shape().1))
            .unwrap_or_else(crate::engine::default_parallelism)
            .max(1)
    }

    /// The warehouse-node count query morsels spread across: the
    /// resolved [`EngineConfig::nodes`] (`snowparkd run-sql --nodes N`
    /// or the `SNOWPARK_NODES` env var), else the pool shape
    /// (`PoolConfig::distributed_query_shape` — the same nodes the UDF
    /// exchange deals batches to), else 1.
    pub fn query_nodes(&self) -> usize {
        self.engine
            .nodes
            .or_else(|| self.pool_config.map(|c| c.distributed_query_shape().0))
            .unwrap_or_else(crate::engine::default_nodes)
            .max(1)
    }

    /// Is the §IV.C adaptive query-shape policy active on this session?
    pub fn adaptive_shape_enabled(&self) -> bool {
        self.adaptive
    }

    /// The per-query node-balance history the adaptive shape policy
    /// consults (fed automatically after every [`Session::sql`] /
    /// [`Session::sql_with_stats`] execution, keyed by SQL text).
    pub fn query_balance_stats(&self) -> &StatsFramework {
        &self.balance_stats
    }

    /// The `(nodes, parallelism)` shape this session would run `text`
    /// with right now: the static shape ([`Session::query_nodes`] ×
    /// [`Session::query_parallelism`]), adapted per the recorded
    /// balance history when [`Session::adaptive_shape_enabled`].
    /// Explicit builder overrides pin their dimension.
    pub fn planned_shape(&self, text: &str) -> (usize, usize) {
        let mut shape = (self.query_nodes(), self.query_parallelism());
        if self.adaptive {
            let picked = self.shape_policy.pick(text, &self.balance_stats, shape);
            if self.engine.nodes.is_none() {
                shape.0 = picked.0;
            }
            if self.engine.parallelism.is_none() {
                shape.1 = picked.1;
            }
        }
        shape
    }

    fn exec_context_for(&self, text: &str) -> ExecContext {
        let (nodes, parallelism) = self.planned_shape(text);
        ExecContext {
            catalog: self.catalog.clone(),
            udfs: Arc::new(self.udfs()),
            udf_stats: self.stats.clone(),
            vectorized: true,
            parallelism,
            nodes,
            steal: true,
            fragments: self.engine.fragments,
            transport: self.pool_config.map(|c| c.transport).unwrap_or_default(),
            tally: Arc::new(crate::engine::ExecTally::default()),
            // A fresh scope per statement: count-based triggers and the
            // blacklist re-arm on every query, like a real transient
            // outage would look to consecutive statements.
            fault: self.engine.fault_plan.clone().map(FaultScope::new),
            cancel: self.query_timeout.map(CancelToken::with_deadline),
            fault_retry: true,
            rewrite: self.engine.rewrite,
            shuffle: self.engine.shuffle,
        }
    }

    /// Statements this session aborted with
    /// [`crate::engine::fault::DeadlineExceeded`] (the per-session
    /// deadline counter behind `--stats`).
    pub fn deadline_exceeded_count(&self) -> u64 {
        self.deadline_exceeded.load(Ordering::Relaxed)
    }

    /// Run a SQL statement on the leader.
    pub fn sql(&self, text: &str) -> Result<RowSet> {
        Ok(self.sql_with_stats(text)?.0)
    }

    /// Run a SQL statement, also returning per-operator rows and
    /// timings. On adaptive sessions, every execution's per-node busy
    /// times feed the session's balance history, closing the §IV.C
    /// adaptive-shape loop for the next run of the same statement.
    /// (Non-adaptive sessions skip the recording — text-keyed history
    /// nobody consults would only accumulate.)
    pub fn sql_with_stats(&self, text: &str) -> Result<(RowSet, crate::engine::QueryStats)> {
        self.sql_with_stats_timeout(text, self.query_timeout)
    }

    /// Like [`Session::sql_with_stats`], but with a per-statement
    /// wall-time bound overriding the session-level
    /// [`SessionBuilder::query_timeout`] (None = unbounded even if the
    /// session has a default). The serving layer uses this to hand each
    /// statement whatever deadline budget remains after admission
    /// queueing.
    pub fn sql_with_stats_timeout(
        &self,
        text: &str,
        timeout: Option<Duration>,
    ) -> Result<(RowSet, crate::engine::QueryStats)> {
        // Static semantic front door (the paper's §III client-side
        // validation): statements that cannot execute are rejected with
        // coded diagnostics before an execution context is even built.
        // `SNOWPARK_ANALYZE=0` (resolved into the session's
        // [`EngineConfig`] at build time) bypasses the gate.
        if self.engine.analyze {
            let analysis = self.check_sql(text);
            if !analysis.is_ok() {
                return Err(anyhow!(
                    "semantic analysis rejected the statement:\n{}",
                    analysis.render_errors()
                ));
            }
        }
        let mut ctx = self.exec_context_for(text);
        ctx.cancel = timeout.map(CancelToken::with_deadline);
        let res = crate::engine::run_sql_with_stats(text, &ctx);
        // Node-health observations feed the shape policy on success AND
        // failure (the tally survives an aborted statement): a node that
        // kept failing this statement should stop being picked for the
        // next one. Recorded only for multi-node dispatches — a
        // leader-only run observes nothing about remote health.
        let node_snapshot = ctx.tally.snapshot();
        if self.adaptive && node_snapshot.len() > 1 {
            let per_node_failures: Vec<u64> =
                node_snapshot.iter().map(|c| c.retries).collect();
            self.balance_stats.record_node_health(&per_node_failures);
        }
        match res {
            Ok((out, stats)) => {
                if self.adaptive {
                    self.balance_stats.record_node_balance(
                        text,
                        &stats.per_node_busy_ns(),
                        stats.total_steals(),
                    );
                }
                Ok((out, stats))
            }
            Err(e) => {
                if crate::engine::fault::is_deadline_exceeded(&e) {
                    self.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
                }
                Err(e)
            }
        }
    }

    /// Statically analyze a statement against this session's catalog and
    /// UDF registry — resolution, type checking, schema/row estimates,
    /// lints, and the fragment-eligibility report — without executing a
    /// row (the `snowparkd check-sql` / `run-sql --explain` entry point).
    pub fn check_sql(&self, text: &str) -> crate::engine::Analysis {
        crate::engine::analyze_sql(text, self.catalog(), &self.udfs())
    }

    /// Open a DataFrame on a table.
    pub fn table(self: &Arc<Self>, name: &str) -> DataFrame {
        DataFrame::from_table(self.clone(), name)
    }

    /// Open a DataFrame over arbitrary SQL.
    pub fn sql_frame(self: &Arc<Self>, sql: &str) -> DataFrame {
        DataFrame::from_sql(self.clone(), sql)
    }

    /// Get (spawning on first use) the interpreter pool.
    pub fn pool(&self) -> Result<Arc<InterpreterPool>> {
        let mut guard = self.pool.lock().unwrap();
        if guard.is_none() {
            let cfg = self
                .pool_config
                .ok_or_else(|| anyhow!("session built without a pool configuration"))?;
            *guard = Some(Arc::new(InterpreterPool::spawn(
                cfg,
                Arc::new(self.udfs()),
                self.stats.clone(),
            )));
        }
        Ok(guard.as_ref().unwrap().clone())
    }

    /// Drop the pool (it respawns with fresh registry state on next use).
    pub fn reset_pool(&self) {
        *self.pool.lock().unwrap() = None;
    }

    /// Distributed UDF projection over a partitioned table (§IV.C): apply
    /// `udf(input_col)` to every row of `table`, routing batches through
    /// the interpreter pool under `mode`. Returns the output column
    /// (ordered: partition 0's rows first) and the exchange report.
    pub fn run_distributed_udf(
        &self,
        table: &str,
        udf: &str,
        input_cols: &[&str],
        mode: ExchangeMode,
    ) -> Result<(Column, ExchangeReport)> {
        let partitions = self
            .partitions_of(table)
            .ok_or_else(|| anyhow!("table {table:?} is not partitioned"))?;
        // Project the UDF's argument columns per partition.
        let projected: Vec<RowSet> = partitions
            .iter()
            .map(|p| {
                let mut fields = Vec::new();
                let mut cols = Vec::new();
                for c in input_cols {
                    let col = p
                        .column_by_name(c)
                        .ok_or_else(|| anyhow!("no column {c:?} in {table:?}"))?
                        .clone();
                    fields.push(Field::new(*c, col.data_type()));
                    cols.push(col);
                }
                RowSet::new(Schema::new(fields), cols)
            })
            .collect::<Result<_>>()?;
        let pool = self.pool()?;
        let registry = self.udfs();
        let cfg = ExchangeConfig { mode, ..self.exchange };
        let (columns, report) = run_udf_exchange(&projected, udf, &pool, &registry, cfg)?;
        // Stitch partition outputs into one column (partition order) by
        // concatenating the typed columns directly — the exchange already
        // typed every partition from the registry's declared return type,
        // so no per-cell `Value` round trips and no dtype re-inference.
        let mut iter = columns.into_iter();
        let mut out = iter
            .next()
            .ok_or_else(|| anyhow!("exchange returned no partitions"))?;
        for c in iter {
            out.append(&c)?;
        }
        Ok((out, report))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::Value;

    fn parts() -> Vec<RowSet> {
        (0..2)
            .map(|p| {
                RowSet::new(
                    Schema::new(vec![Field::new("x", DataType::Float64)]),
                    vec![Column::from_f64(
                        (0..10).map(|i| (p * 100 + i) as f64).collect(),
                    )],
                )
                .unwrap()
            })
            .collect()
    }

    #[test]
    fn partitioned_table_also_queryable_merged() {
        let s = Session::builder().build().unwrap();
        s.register_partitioned("events", parts()).unwrap();
        let rs = s.sql("SELECT COUNT(*) AS n FROM events").unwrap();
        assert_eq!(rs.row(0)[0], Value::Int(20));
        assert_eq!(s.partitions_of("events").unwrap().len(), 2);
        assert!(s.partitions_of("missing").is_none());
    }

    #[test]
    fn distributed_udf_round_trip() {
        let s = Session::builder()
            .pool(PoolConfig { nodes: 2, procs_per_node: 2, ..Default::default() })
            .build()
            .unwrap();
        s.register_partitioned("events", parts()).unwrap();
        s.register_scalar_udf(
            "plus1",
            DataType::Float64,
            Arc::new(|args| Ok(Value::Float(args[0].as_f64().unwrap_or(0.0) + 1.0))),
        );
        for mode in [ExchangeMode::Local, ExchangeMode::RoundRobin] {
            let (col, report) = s
                .run_distributed_udf("events", "plus1", &["x"], mode)
                .unwrap();
            assert_eq!(col.len(), 20);
            assert_eq!(col.value(0), Value::Float(1.0));
            assert_eq!(col.value(10), Value::Float(101.0));
            assert_eq!(report.rows, 20);
        }
    }

    #[test]
    fn pool_requires_config() {
        let s = Session::builder().build().unwrap();
        assert!(s.pool().is_err());
    }

    #[test]
    fn parallelism_derived_from_warehouse_shape() {
        // With a pool: one morsel worker per interpreter process on a
        // node, and morsels spread across the pool's nodes.
        let s = Session::builder()
            .pool(PoolConfig { nodes: 2, procs_per_node: 3, ..Default::default() })
            .build()
            .unwrap();
        assert_eq!(s.query_parallelism(), 3);
        assert_eq!(s.query_nodes(), 2);
        // Explicit overrides win.
        let s = Session::builder().parallelism(7).nodes(3).build().unwrap();
        assert_eq!(s.query_parallelism(), 7);
        assert_eq!(s.query_nodes(), 3);
        // Pool-less sessions fall back to the engine defaults.
        let s = Session::builder().build().unwrap();
        assert!(s.query_parallelism() >= 1);
        assert!(s.query_nodes() >= 1);
    }

    #[test]
    fn adaptive_shape_consults_balance_history() {
        const MS: u64 = 1_000_000;
        let s = Session::builder()
            .pool(PoolConfig { nodes: 4, procs_per_node: 2, ..Default::default() })
            .adaptive_shape(true)
            .build()
            .unwrap();
        assert!(s.adaptive_shape_enabled());
        // Cold start: the pool shape.
        assert_eq!(s.planned_shape("SELECT 1"), (4, 2));
        // Skewed, heavy history → fewer nodes.
        let q = "SELECT skewed";
        for _ in 0..3 {
            s.query_balance_stats().record_node_balance(q, &[80 * MS, 5 * MS, 4 * MS], 9);
        }
        assert_eq!(s.planned_shape(q), (2, 2));
        // Tiny queries stay on the leader.
        let q2 = "SELECT tiny";
        for _ in 0..3 {
            s.query_balance_stats().record_node_balance(q2, &[200_000, 190_000], 0);
        }
        // (Parallelism adapts down with it: ~0.4 ms of busy time funds
        // a single worker at the policy's 0.5 ms/worker floor.)
        assert_eq!(s.planned_shape(q2), (1, 1));
        // Balanced heavy history → full scale-out.
        let q3 = "SELECT balanced";
        for _ in 0..3 {
            s.query_balance_stats()
                .record_node_balance(q3, &[50 * MS, 48 * MS, 52 * MS, 49 * MS], 2);
        }
        assert_eq!(s.planned_shape(q3), (4, 2));
        // Explicit builder overrides pin their dimension.
        let s = Session::builder()
            .pool(PoolConfig { nodes: 4, procs_per_node: 2, ..Default::default() })
            .nodes(3)
            .adaptive_shape(true)
            .build()
            .unwrap();
        for _ in 0..3 {
            s.query_balance_stats().record_node_balance(q, &[80 * MS, 5 * MS, 4 * MS], 9);
        }
        assert_eq!(s.planned_shape(q).0, 3);
        // adaptive_shape(false) freezes the static shape.
        let s = Session::builder()
            .pool(PoolConfig { nodes: 4, procs_per_node: 2, ..Default::default() })
            .adaptive_shape(false)
            .build()
            .unwrap();
        assert!(!s.adaptive_shape_enabled());
        assert_eq!(s.planned_shape(q), (4, 2));
        // Pool-less sessions default off (unless the env var forces it).
        if std::env::var("SNOWPARK_ADAPTIVE_SHAPE").is_err() {
            let s = Session::builder().build().unwrap();
            assert!(!s.adaptive_shape_enabled());
        }
    }

    #[test]
    fn sql_feeds_balance_history() {
        // A multi-node session's SQL executions record node-balance
        // observations keyed by statement text, so the adaptive loop
        // closes without any caller involvement.
        let rows = 20_000usize;
        let s = Session::builder()
            .pool(PoolConfig { nodes: 2, procs_per_node: 2, ..Default::default() })
            .adaptive_shape(true)
            .build()
            .unwrap();
        s.catalog().register(
            "t",
            RowSet::new(
                Schema::new(vec![Field::new("x", DataType::Float64)]),
                vec![Column::from_f64((0..rows).map(|i| (i % 997) as f64).collect())],
            )
            .unwrap(),
        );
        let q = "SELECT x, COUNT(*) AS n FROM t GROUP BY x";
        let first = s.sql(q).unwrap();
        let h = s.query_balance_stats().balance_lookback(q, 8);
        assert_eq!(h.len(), 1, "execution should record one observation");
        assert!(h[0].skew >= 1.0);
        // Re-running is shape-stable in output regardless of what the
        // policy picks next (byte-identity at every shape).
        let second = s.sql(q).unwrap();
        assert_eq!(first, second);
    }

    #[test]
    fn sql_runs_across_pool_nodes() {
        // A session whose pool spans nodes runs its SQL through the node
        // dispatch path; outputs must match a single-node session.
        let rows = 20_000usize;
        let xs: Vec<f64> = (0..rows).map(|i| (i % 997) as f64).collect();
        let make = |nodes: usize| {
            let s = Session::builder()
                .pool(PoolConfig { nodes, procs_per_node: 2, ..Default::default() })
                .build()
                .unwrap();
            s.catalog().register(
                "t",
                RowSet::new(
                    Schema::new(vec![Field::new("x", DataType::Float64)]),
                    vec![Column::from_f64(xs.clone())],
                )
                .unwrap(),
            );
            s
        };
        let q = "SELECT x, COUNT(*) AS n FROM t GROUP BY x ORDER BY n DESC, x LIMIT 7";
        let single = make(1).sql(q).unwrap();
        let multi = make(3).sql(q).unwrap();
        assert_eq!(single, multi);
    }

    fn register_big_table(s: &Session) {
        let rows = 20_000usize;
        s.catalog().register(
            "t",
            RowSet::new(
                Schema::new(vec![Field::new("x", DataType::Float64)]),
                vec![Column::from_f64((0..rows).map(|i| (i % 997) as f64).collect())],
            )
            .unwrap(),
        );
    }

    #[test]
    fn query_timeout_surfaces_deadline_exceeded() {
        // A 2-node session with a 120s injected stall on node 1 and a
        // 200ms deadline: the statement must return DeadlineExceeded
        // promptly instead of hanging, and the session counts it.
        let s = Session::builder()
            .nodes(2)
            .parallelism(2)
            .adaptive_shape(false)
            .query_timeout(Duration::from_millis(200))
            .fault_plan(FaultPlan::parse("seed=1;slow=1:120000").unwrap())
            .build()
            .unwrap();
        register_big_table(&s);
        let started = std::time::Instant::now();
        let err = s.sql("SELECT x, COUNT(*) AS n FROM t GROUP BY x").unwrap_err();
        assert!(crate::engine::fault::is_deadline_exceeded(&err), "{err:#}");
        assert!(started.elapsed() < Duration::from_secs(30), "{:?}", started.elapsed());
        assert_eq!(s.deadline_exceeded_count(), 1);
        // An untimed statement on a fresh session still works.
        let s2 = Session::builder().nodes(1).parallelism(2).build().unwrap();
        register_big_table(&s2);
        assert!(s2.sql("SELECT COUNT(*) AS n FROM t").is_ok());
        assert_eq!(s2.deadline_exceeded_count(), 0);
    }

    #[test]
    fn shared_catalog_spans_sessions() {
        // Two sessions over one catalog: a table registered through one
        // is queryable from the other, with zero data cloning.
        let catalog = Arc::new(crate::engine::Catalog::new());
        let a = Session::builder().shared_catalog(catalog.clone()).build().unwrap();
        let b = Session::builder().shared_catalog(catalog).build().unwrap();
        register_big_table(&a);
        let n = b.sql("SELECT COUNT(*) AS n FROM t").unwrap().row(0)[0]
            .as_i64()
            .unwrap();
        assert_eq!(n, 20_000);
        // An unshared session stays isolated.
        let c = Session::builder().build().unwrap();
        assert!(c.sql("SELECT COUNT(*) AS n FROM t").is_err());
    }

    #[test]
    fn per_statement_timeout_overrides_session_default() {
        // Session has no default timeout; a tight per-statement deadline
        // against an injected stall must still cut the query, and a
        // subsequent unbounded statement on the same session must run.
        let s = Session::builder()
            .nodes(2)
            .parallelism(2)
            .adaptive_shape(false)
            .fault_plan(FaultPlan::parse("seed=1;slow=1:120000").unwrap())
            .build()
            .unwrap();
        register_big_table(&s);
        let err = s
            .sql_with_stats_timeout(
                "SELECT x, COUNT(*) AS n FROM t GROUP BY x",
                Some(Duration::from_millis(200)),
            )
            .unwrap_err();
        assert!(crate::engine::fault::is_deadline_exceeded(&err), "{err:#}");
        assert_eq!(s.deadline_exceeded_count(), 1);
        // None = unbounded; a fresh fault-free session runs normally.
        let s2 = Session::builder().nodes(1).parallelism(2).build().unwrap();
        register_big_table(&s2);
        assert!(s2
            .sql_with_stats_timeout("SELECT COUNT(*) AS n FROM t", None)
            .is_ok());
    }

    #[test]
    fn flaky_node_health_caps_adaptive_fanout() {
        let s = Session::builder()
            .pool(PoolConfig { nodes: 4, procs_per_node: 2, ..Default::default() })
            .adaptive_shape(true)
            .build()
            .unwrap();
        assert_eq!(s.planned_shape("SELECT 1"), (4, 2));
        // Two observations of node 1 failing: flaky → fan-out capped
        // below it.
        s.query_balance_stats().record_node_health(&[0, 3, 0, 0]);
        s.query_balance_stats().record_node_health(&[0, 2, 0, 0]);
        assert_eq!(s.planned_shape("SELECT 1"), (1, 2));
        // Clean statements age the failures out and the shape recovers.
        for _ in 0..16 {
            s.query_balance_stats().record_node_health(&[0, 0, 0, 0]);
        }
        assert_eq!(s.planned_shape("SELECT 1"), (4, 2));
    }

    #[test]
    fn sql_failures_feed_node_health() {
        // An adaptive session whose fault plan makes node 1 fail every
        // shipment: after two statements' worth of observed retries, the
        // shape policy stops fanning out past the flaky node.
        let s = Session::builder()
            .pool(PoolConfig { nodes: 2, procs_per_node: 2, ..Default::default() })
            .adaptive_shape(true)
            .fault_plan(FaultPlan::parse("seed=2;ship=1:99").unwrap())
            .build()
            .unwrap();
        register_big_table(&s);
        // Two *distinct* statements (balance history is keyed by text,
        // so each starts cold at the pool shape and actually fans out),
        // giving two global health observations of node 1 failing.
        // Recovery keeps both statements correct while node 1 burns.
        assert!(s.sql("SELECT x, COUNT(*) AS n FROM t GROUP BY x").is_ok());
        assert!(s.sql("SELECT x, SUM(x) AS sx FROM t GROUP BY x").is_ok());
        assert!(s.query_balance_stats().node_flaky(1, 2, 0.5));
        // A brand-new statement (no balance history of its own) now
        // plans leader-only: the health clamp, not the balance rule.
        assert_eq!(s.planned_shape("SELECT COUNT(*) AS n FROM t").0, 1);
    }

    #[test]
    fn distributed_udf_keeps_declared_dtype() {
        // A UDF that returns NULL for every row of the first partition:
        // the output column must still carry the declared Float64 dtype
        // (not a Float64-by-fallback that breaks for other decls), and
        // all-Int UDFs must come back Int64.
        let s = Session::builder()
            .pool(PoolConfig { nodes: 2, procs_per_node: 2, ..Default::default() })
            .build()
            .unwrap();
        s.register_partitioned("events", parts()).unwrap();
        s.register_scalar_udf(
            "to_int",
            DataType::Int64,
            Arc::new(|args| Ok(Value::Int(args[0].as_f64().unwrap_or(0.0) as i64))),
        );
        let (col, _) = s
            .run_distributed_udf("events", "to_int", &["x"], ExchangeMode::Local)
            .unwrap();
        assert_eq!(col.data_type(), DataType::Int64);
        assert_eq!(col.len(), 20);
        s.register_scalar_udf("all_null", DataType::Float64, Arc::new(|_| Ok(Value::Null)));
        let (col, _) = s
            .run_distributed_udf("events", "all_null", &["x"], ExchangeMode::Local)
            .unwrap();
        assert_eq!(col.data_type(), DataType::Float64);
        assert!((0..col.len()).all(|i| !col.is_valid(i)));
        // Declared Int64 but emits floats: widened (like the inline
        // expression path), never silently truncated.
        s.register_scalar_udf(
            "halvef",
            DataType::Int64,
            Arc::new(|args| Ok(Value::Float(args[0].as_f64().unwrap_or(0.0) / 2.0))),
        );
        let (col, _) = s
            .run_distributed_udf("events", "halvef", &["x"], ExchangeMode::Local)
            .unwrap();
        assert_eq!(col.data_type(), DataType::Float64);
        assert_eq!(col.value(1), Value::Float(0.5));
    }
}
