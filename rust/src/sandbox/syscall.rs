//! Syscall filtering (§III.C): "a syscall filtering layer to make sure
//! insecure syscalls are blocked. The layer maintains a list of allowed or
//! conditionally allowed syscalls and denies other potentially malicious
//! syscalls."
//!
//! Default-deny policy engine. Conditional rules carry an argument
//! predicate (e.g. `socket` allowed only for AF_UNIX; `openat` allowed
//! only under the sandbox root).

use std::collections::HashMap;
use std::sync::Arc;

/// One (simulated) syscall invocation.
#[derive(Debug, Clone)]
pub struct Syscall {
    pub name: String,
    /// Coarse argument model: string key/value pairs the predicates read
    /// (e.g. "family" => "AF_INET", "path" => "/etc/shadow").
    pub args: Vec<(String, String)>,
}

impl Syscall {
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), args: Vec::new() }
    }

    pub fn with_arg(mut self, key: &str, value: &str) -> Self {
        self.args.push((key.to_string(), value.to_string()));
        self
    }

    pub fn arg(&self, key: &str) -> Option<&str> {
        self.args
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }
}

/// Filter decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    Allow,
    Deny,
}

/// Per-syscall policy.
#[derive(Clone)]
pub enum SyscallPolicy {
    Allow,
    /// Allowed only when the predicate accepts the arguments.
    Conditional(Arc<dyn Fn(&Syscall) -> bool + Send + Sync>),
}

/// The filter: name → policy; anything unlisted is denied.
#[derive(Clone, Default)]
pub struct SyscallFilter {
    rules: HashMap<String, SyscallPolicy>,
}

impl SyscallFilter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn allow(&mut self, name: &str) -> &mut Self {
        self.rules.insert(name.to_string(), SyscallPolicy::Allow);
        self
    }

    pub fn allow_if(
        &mut self,
        name: &str,
        pred: impl Fn(&Syscall) -> bool + Send + Sync + 'static,
    ) -> &mut Self {
        self.rules
            .insert(name.to_string(), SyscallPolicy::Conditional(Arc::new(pred)));
        self
    }

    pub fn check(&self, call: &Syscall) -> Verdict {
        match self.rules.get(&call.name) {
            None => Verdict::Deny,
            Some(SyscallPolicy::Allow) => Verdict::Allow,
            Some(SyscallPolicy::Conditional(pred)) => {
                if pred(call) {
                    Verdict::Allow
                } else {
                    Verdict::Deny
                }
            }
        }
    }

    pub fn rule_count(&self) -> usize {
        self.rules.len()
    }

    /// The default Snowpark-like policy: compute and in-sandbox I/O are
    /// allowed; introspection, privilege, and raw-network calls are not.
    /// Network `connect` is conditionally allowed only toward the local
    /// egress proxy (the proxy applies the §III.C egress policies).
    pub fn default_policy() -> Self {
        let mut f = SyscallFilter::new();
        for name in [
            "read", "write", "close", "fstat", "lseek", "mmap", "munmap",
            "brk", "rt_sigaction", "rt_sigprocmask", "ioctl", "pread64",
            "pwrite64", "readv", "writev", "pipe", "select", "poll",
            "epoll_wait", "epoll_ctl", "epoll_create1", "dup", "dup2",
            "nanosleep", "getpid", "gettid", "exit", "exit_group", "futex",
            "clock_gettime", "getrandom", "sched_yield", "madvise",
        ] {
            f.allow(name);
        }
        // Filesystem access only under the sandbox root or /tmp scratch.
        f.allow_if("openat", |c| {
            c.arg("path")
                .map(|p| p.starts_with("/sandbox/") || p.starts_with("/tmp/"))
                .unwrap_or(false)
        });
        f.allow_if("unlink", |c| {
            c.arg("path").map(|p| p.starts_with("/tmp/")).unwrap_or(false)
        });
        // Process creation: fork/clone allowed without CLONE_NEWUSER
        // escalation flags.
        f.allow_if("clone", |c| {
            c.arg("flags")
                .map(|fl| !fl.contains("CLONE_NEWUSER"))
                .unwrap_or(true)
        });
        // Sockets: UNIX-domain only (gRPC to the worker), or TCP to the
        // egress proxy.
        f.allow_if("socket", |c| c.arg("family") == Some("AF_UNIX"));
        f.allow_if("connect", |c| {
            c.arg("dest") == Some("egress-proxy") || c.arg("family") == Some("AF_UNIX")
        });
        f
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_deny() {
        let f = SyscallFilter::new();
        assert_eq!(f.check(&Syscall::new("read")), Verdict::Deny);
    }

    #[test]
    fn allow_list() {
        let f = SyscallFilter::default_policy();
        assert_eq!(f.check(&Syscall::new("read")), Verdict::Allow);
        assert_eq!(f.check(&Syscall::new("write")), Verdict::Allow);
        assert_eq!(f.check(&Syscall::new("ptrace")), Verdict::Deny);
        assert_eq!(f.check(&Syscall::new("mount")), Verdict::Deny);
        assert_eq!(f.check(&Syscall::new("setuid")), Verdict::Deny);
        assert_eq!(f.check(&Syscall::new("kexec_load")), Verdict::Deny);
    }

    #[test]
    fn conditional_openat_paths() {
        let f = SyscallFilter::default_policy();
        let ok = Syscall::new("openat").with_arg("path", "/sandbox/data/x.parquet");
        let tmp = Syscall::new("openat").with_arg("path", "/tmp/scratch");
        let bad = Syscall::new("openat").with_arg("path", "/etc/shadow");
        let none = Syscall::new("openat");
        assert_eq!(f.check(&ok), Verdict::Allow);
        assert_eq!(f.check(&tmp), Verdict::Allow);
        assert_eq!(f.check(&bad), Verdict::Deny);
        assert_eq!(f.check(&none), Verdict::Deny);
    }

    #[test]
    fn conditional_sockets() {
        let f = SyscallFilter::default_policy();
        let unix = Syscall::new("socket").with_arg("family", "AF_UNIX");
        let inet = Syscall::new("socket").with_arg("family", "AF_INET");
        assert_eq!(f.check(&unix), Verdict::Allow);
        assert_eq!(f.check(&inet), Verdict::Deny);
        let proxy = Syscall::new("connect").with_arg("dest", "egress-proxy");
        let direct = Syscall::new("connect").with_arg("dest", "evil.example.com:443");
        assert_eq!(f.check(&proxy), Verdict::Allow);
        assert_eq!(f.check(&direct), Verdict::Deny);
    }

    #[test]
    fn clone_escalation_blocked() {
        let f = SyscallFilter::default_policy();
        let ok = Syscall::new("clone").with_arg("flags", "CLONE_VM|CLONE_FS");
        let bad = Syscall::new("clone").with_arg("flags", "CLONE_VM|CLONE_NEWUSER");
        assert_eq!(f.check(&ok), Verdict::Allow);
        assert_eq!(f.check(&bad), Verdict::Deny);
    }

    #[test]
    fn policy_is_extensible() {
        // §III.C: "these syscall mechanisms have evolved ... providing
        // more functionality inside the sandbox — for example, adding
        // external network access".
        let mut f = SyscallFilter::default_policy();
        let n = f.rule_count();
        f.allow_if("socket", |c| {
            matches!(c.arg("family"), Some("AF_UNIX") | Some("AF_INET"))
        });
        assert_eq!(f.rule_count(), n); // replaced, not duplicated
        let inet = Syscall::new("socket").with_arg("family", "AF_INET");
        assert_eq!(f.check(&inet), Verdict::Allow);
    }
}
