//! The Snowpark secure sandbox (§III.C, Fig. 3), as a policy-engine
//! simulation: the paper's claims here are architectural (layered
//! defense-in-depth), so we reproduce the *mechanisms* — namespace
//! isolation, cgroup resource control, syscall filtering with a
//! supervisor audit log, and network egress policies — and test their
//! invariants, rather than shelling out to a real kernel.
//!
//! Layers (outermost first):
//! 1. namespaces + cgroups — process isolation and resource limits;
//! 2. syscall filtering — allow / conditionally-allow / deny;
//! 3. supervisor — denied-syscall audit log and anomaly detection;
//! 4. network egress policies — control-plane-generated, enforced at the
//!    edge, so even a fully-compromised sandbox cannot exfiltrate.

mod cgroup;
mod egress;
mod namespace;
mod supervisor;
mod syscall;

pub use cgroup::{CgroupController, CgroupError, CgroupLimits};
pub use egress::{EgressDecision, EgressPolicy, EgressProxy, EgressRule};
pub use namespace::{NamespaceKind, NamespaceSet};
pub use supervisor::{Supervisor, SupervisorEvent};
pub use syscall::{Syscall, SyscallFilter, SyscallPolicy, Verdict};

use crate::util::ids::ProcId;

/// A fully-assembled sandbox: the layered defenses wired together for one
/// set of interpreter processes.
pub struct Sandbox {
    pub namespaces: NamespaceSet,
    pub cgroup: CgroupController,
    pub filter: SyscallFilter,
    pub supervisor: Supervisor,
    pub egress: EgressProxy,
}

impl Sandbox {
    /// Standard Snowpark sandbox: full namespace isolation, the default
    /// syscall policy, and the given cgroup limits + egress policy.
    pub fn standard(limits: CgroupLimits, egress: EgressPolicy) -> Self {
        Self {
            namespaces: NamespaceSet::full(),
            cgroup: CgroupController::new(limits),
            filter: SyscallFilter::default_policy(),
            supervisor: Supervisor::new(),
            egress: EgressProxy::new(egress),
        }
    }

    /// Adjudicate one syscall from a sandboxed process: the filter decides,
    /// the supervisor logs denials (§III.C: "track all denied syscalls").
    pub fn check_syscall(&self, proc: ProcId, call: &Syscall) -> Verdict {
        let verdict = self.filter.check(call);
        if verdict == Verdict::Deny {
            self.supervisor.record_denial(proc, call);
        }
        verdict
    }

    /// Tear down the sandbox (query end): interpreters and cgroup charges
    /// are released; caches (which live on the node, not in the sandbox)
    /// survive, matching §III.B.
    pub fn teardown(&mut self) {
        self.cgroup.release_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_sandbox_denies_and_logs() {
        let sb = Sandbox::standard(CgroupLimits::default(), EgressPolicy::deny_all());
        let v = sb.check_syscall(ProcId(1), &Syscall::new("ptrace"));
        assert_eq!(v, Verdict::Deny);
        assert_eq!(sb.supervisor.denial_count(), 1);
        // Allowed syscalls are not logged.
        let v = sb.check_syscall(ProcId(1), &Syscall::new("read"));
        assert_eq!(v, Verdict::Allow);
        assert_eq!(sb.supervisor.denial_count(), 1);
    }

    #[test]
    fn teardown_releases_memory_charges() {
        let mut sb = Sandbox::standard(CgroupLimits::default(), EgressPolicy::deny_all());
        sb.cgroup.charge_memory(ProcId(1), 1 << 20).unwrap();
        assert!(sb.cgroup.memory_used() > 0);
        sb.teardown();
        assert_eq!(sb.cgroup.memory_used(), 0);
    }
}
