//! Cgroup resource control (§III.C: "cgroups to manage resources, such as
//! CPU and memory").
//!
//! The controller is the accounting object the rest of the system trusts:
//! interpreter processes charge memory against it as they allocate, and
//! exceeding the limit produces the OOM kill that §IV.B's scheduler is
//! designed to avoid.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::util::ids::ProcId;

/// Resource limits for one sandbox.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CgroupLimits {
    pub memory_bytes: u64,
    /// CPU weight (cgroup v2 `cpu.weight`, 1..=10000).
    pub cpu_weight: u32,
    /// Max processes (pids controller).
    pub pids_max: u32,
}

impl Default for CgroupLimits {
    fn default() -> Self {
        Self { memory_bytes: 2 << 30, cpu_weight: 100, pids_max: 512 }
    }
}

/// Errors surfaced by the controller.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CgroupError {
    /// The charge would exceed `memory.max` — the kernel would OOM-kill.
    OutOfMemory { requested: u64, used: u64, limit: u64 },
    /// Process-count limit hit.
    TooManyPids { limit: u32 },
}

impl std::fmt::Display for CgroupError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CgroupError::OutOfMemory { requested, used, limit } => write!(
                f,
                "cgroup OOM: requested {requested}B with {used}B/{limit}B used"
            ),
            CgroupError::TooManyPids { limit } => write!(f, "pids limit {limit} reached"),
        }
    }
}

impl std::error::Error for CgroupError {}

/// Per-sandbox resource accounting + enforcement.
pub struct CgroupController {
    limits: CgroupLimits,
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    mem_by_proc: HashMap<ProcId, u64>,
    peak_memory: u64,
    oom_kills: u64,
}

impl CgroupController {
    pub fn new(limits: CgroupLimits) -> Self {
        Self { limits, inner: Mutex::new(Inner::default()) }
    }

    pub fn limits(&self) -> CgroupLimits {
        self.limits
    }

    /// Register a process; fails when the pids limit is reached.
    pub fn attach(&self, proc: ProcId) -> Result<(), CgroupError> {
        let mut inner = self.inner.lock().unwrap();
        if inner.mem_by_proc.len() as u32 >= self.limits.pids_max {
            return Err(CgroupError::TooManyPids { limit: self.limits.pids_max });
        }
        inner.mem_by_proc.entry(proc).or_insert(0);
        Ok(())
    }

    /// Charge `bytes` of memory to `proc`. On breach the process's
    /// charges are dropped (the OOM killer reaped it) and an error
    /// returns to the caller.
    pub fn charge_memory(&self, proc: ProcId, bytes: u64) -> Result<(), CgroupError> {
        let mut inner = self.inner.lock().unwrap();
        let used: u64 = inner.mem_by_proc.values().sum();
        if used + bytes > self.limits.memory_bytes {
            inner.mem_by_proc.remove(&proc);
            inner.oom_kills += 1;
            return Err(CgroupError::OutOfMemory {
                requested: bytes,
                used,
                limit: self.limits.memory_bytes,
            });
        }
        *inner.mem_by_proc.entry(proc).or_insert(0) += bytes;
        let now: u64 = inner.mem_by_proc.values().sum();
        inner.peak_memory = inner.peak_memory.max(now);
        Ok(())
    }

    /// Return memory from `proc` (e.g. a batch completed).
    pub fn uncharge_memory(&self, proc: ProcId, bytes: u64) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(m) = inner.mem_by_proc.get_mut(&proc) {
            *m = m.saturating_sub(bytes);
        }
    }

    pub fn memory_used(&self) -> u64 {
        self.inner.lock().unwrap().mem_by_proc.values().sum()
    }

    /// Peak concurrent memory across the sandbox's lifetime — this is the
    /// value §IV.B's stats framework records per query execution.
    pub fn peak_memory(&self) -> u64 {
        self.inner.lock().unwrap().peak_memory
    }

    pub fn oom_kills(&self) -> u64 {
        self.inner.lock().unwrap().oom_kills
    }

    pub fn proc_count(&self) -> usize {
        self.inner.lock().unwrap().mem_by_proc.len()
    }

    /// Drop all charges (sandbox teardown).
    pub fn release_all(&self) {
        self.inner.lock().unwrap().mem_by_proc.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn limits(mem: u64) -> CgroupLimits {
        CgroupLimits { memory_bytes: mem, cpu_weight: 100, pids_max: 4 }
    }

    #[test]
    fn charges_accumulate_and_release() {
        let cg = CgroupController::new(limits(1000));
        cg.charge_memory(ProcId(1), 300).unwrap();
        cg.charge_memory(ProcId(2), 300).unwrap();
        assert_eq!(cg.memory_used(), 600);
        cg.uncharge_memory(ProcId(1), 300);
        assert_eq!(cg.memory_used(), 300);
        assert_eq!(cg.peak_memory(), 600);
    }

    #[test]
    fn breach_is_oom_and_reaps_offender() {
        let cg = CgroupController::new(limits(1000));
        cg.charge_memory(ProcId(1), 800).unwrap();
        let err = cg.charge_memory(ProcId(2), 500).unwrap_err();
        assert!(matches!(err, CgroupError::OutOfMemory { .. }));
        assert_eq!(cg.oom_kills(), 1);
        // Offender's charges dropped; survivor unaffected.
        assert_eq!(cg.memory_used(), 800);
    }

    #[test]
    fn pids_limit() {
        let cg = CgroupController::new(limits(1000));
        for i in 0..4 {
            cg.attach(ProcId(i)).unwrap();
        }
        assert!(matches!(
            cg.attach(ProcId(99)),
            Err(CgroupError::TooManyPids { .. })
        ));
        // Re-attaching an existing proc is fine (idempotent)? It hits the
        // pids cap first — by design, attach checks capacity before entry.
    }

    #[test]
    fn peak_tracks_high_watermark() {
        let cg = CgroupController::new(limits(10_000));
        cg.charge_memory(ProcId(1), 4_000).unwrap();
        cg.uncharge_memory(ProcId(1), 4_000);
        cg.charge_memory(ProcId(1), 2_000).unwrap();
        assert_eq!(cg.peak_memory(), 4_000);
    }

    #[test]
    fn uncharge_saturates() {
        let cg = CgroupController::new(limits(1000));
        cg.charge_memory(ProcId(1), 100).unwrap();
        cg.uncharge_memory(ProcId(1), 500);
        assert_eq!(cg.memory_used(), 0);
    }
}
