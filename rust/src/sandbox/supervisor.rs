//! Supervisor process (§III.C): "logging capabilities to track all denied
//! syscalls in the sandbox. We leverage these logging data to monitor
//! workloads' patterns and identify potential malicious actors."

use std::collections::HashMap;
use std::sync::Mutex;

use super::syscall::Syscall;
use crate::util::ids::ProcId;

/// One audit-log entry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SupervisorEvent {
    pub proc: ProcId,
    pub syscall: String,
    pub seq: u64,
}

/// The supervisor: denial audit log + per-process counters + a simple
/// anomaly heuristic (processes probing many distinct denied syscalls).
#[derive(Default)]
pub struct Supervisor {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    log: Vec<SupervisorEvent>,
    by_proc: HashMap<ProcId, HashMap<String, u64>>,
    seq: u64,
}

impl Supervisor {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_denial(&self, proc: ProcId, call: &Syscall) {
        let mut inner = self.inner.lock().unwrap();
        inner.seq += 1;
        let seq = inner.seq;
        inner.log.push(SupervisorEvent { proc, syscall: call.name.clone(), seq });
        *inner
            .by_proc
            .entry(proc)
            .or_default()
            .entry(call.name.clone())
            .or_insert(0) += 1;
    }

    pub fn denial_count(&self) -> usize {
        self.inner.lock().unwrap().log.len()
    }

    pub fn denials_for(&self, proc: ProcId) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .by_proc
            .get(&proc)
            .map(|m| m.values().sum())
            .unwrap_or(0)
    }

    /// Distinct denied syscalls for a process — a probing signature.
    pub fn distinct_denied(&self, proc: ProcId) -> usize {
        self.inner
            .lock()
            .unwrap()
            .by_proc
            .get(&proc)
            .map(|m| m.len())
            .unwrap_or(0)
    }

    /// Processes whose denial pattern looks like active probing: more
    /// than `distinct_threshold` distinct denied syscalls.
    pub fn suspicious_procs(&self, distinct_threshold: usize) -> Vec<ProcId> {
        let inner = self.inner.lock().unwrap();
        let mut out: Vec<ProcId> = inner
            .by_proc
            .iter()
            .filter(|(_, m)| m.len() > distinct_threshold)
            .map(|(&p, _)| p)
            .collect();
        out.sort();
        out
    }

    /// The most recent `n` events (operator console view).
    pub fn tail(&self, n: usize) -> Vec<SupervisorEvent> {
        let inner = self.inner.lock().unwrap();
        inner.log.iter().rev().take(n).rev().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_and_counters() {
        let s = Supervisor::new();
        s.record_denial(ProcId(1), &Syscall::new("ptrace"));
        s.record_denial(ProcId(1), &Syscall::new("ptrace"));
        s.record_denial(ProcId(2), &Syscall::new("mount"));
        assert_eq!(s.denial_count(), 3);
        assert_eq!(s.denials_for(ProcId(1)), 2);
        assert_eq!(s.denials_for(ProcId(2)), 1);
        assert_eq!(s.denials_for(ProcId(3)), 0);
        assert_eq!(s.distinct_denied(ProcId(1)), 1);
    }

    #[test]
    fn probing_detection() {
        let s = Supervisor::new();
        // proc 7 probes many syscalls; proc 1 just repeats one.
        for name in ["ptrace", "mount", "setuid", "reboot", "init_module"] {
            s.record_denial(ProcId(7), &Syscall::new(name));
        }
        for _ in 0..100 {
            s.record_denial(ProcId(1), &Syscall::new("socket"));
        }
        assert_eq!(s.suspicious_procs(3), vec![ProcId(7)]);
        assert!(s.suspicious_procs(10).is_empty());
    }

    #[test]
    fn tail_returns_most_recent_in_order() {
        let s = Supervisor::new();
        for i in 0..10 {
            s.record_denial(ProcId(i), &Syscall::new("x"));
        }
        let t = s.tail(3);
        assert_eq!(t.len(), 3);
        assert_eq!(t[0].proc, ProcId(7));
        assert_eq!(t[2].proc, ProcId(9));
        assert!(t[0].seq < t[1].seq && t[1].seq < t[2].seq);
    }
}
