//! Namespace isolation (§III.C: "We use namespaces to isolate processes").
//!
//! Simulation of the Linux namespace kinds a Snowpark sandbox unshares.
//! The invariant we test: two sandboxes never share a namespace instance
//! unless explicitly configured to (there is no sharing API — full
//! isolation by construction).

use std::sync::atomic::{AtomicU64, Ordering};

/// Linux namespace kinds relevant to the sandbox.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum NamespaceKind {
    Pid,
    Mount,
    Network,
    Uts,
    Ipc,
    User,
    Cgroup,
}

pub const ALL_KINDS: [NamespaceKind; 7] = [
    NamespaceKind::Pid,
    NamespaceKind::Mount,
    NamespaceKind::Network,
    NamespaceKind::Uts,
    NamespaceKind::Ipc,
    NamespaceKind::User,
    NamespaceKind::Cgroup,
];

static NEXT_NS_ID: AtomicU64 = AtomicU64::new(1);

/// The set of (fresh) namespaces one sandbox owns.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NamespaceSet {
    /// (kind, unique instance id) — ids are globally unique, so equality
    /// of ids across sandboxes would indicate (forbidden) sharing.
    members: Vec<(NamespaceKind, u64)>,
}

impl NamespaceSet {
    /// Unshare every namespace kind (the standard Snowpark sandbox).
    pub fn full() -> Self {
        Self {
            members: ALL_KINDS
                .iter()
                .map(|&k| (k, NEXT_NS_ID.fetch_add(1, Ordering::Relaxed)))
                .collect(),
        }
    }

    /// Unshare only the given kinds (e.g. a lighter sandbox for UDFs that
    /// need host networking through the egress proxy).
    pub fn of(kinds: &[NamespaceKind]) -> Self {
        Self {
            members: kinds
                .iter()
                .map(|&k| (k, NEXT_NS_ID.fetch_add(1, Ordering::Relaxed)))
                .collect(),
        }
    }

    pub fn has(&self, kind: NamespaceKind) -> bool {
        self.members.iter().any(|(k, _)| *k == kind)
    }

    pub fn id_of(&self, kind: NamespaceKind) -> Option<u64> {
        self.members.iter().find(|(k, _)| *k == kind).map(|(_, id)| *id)
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_set_has_every_kind() {
        let ns = NamespaceSet::full();
        for k in ALL_KINDS {
            assert!(ns.has(k), "{k:?}");
        }
        assert_eq!(ns.len(), 7);
    }

    #[test]
    fn sandboxes_never_share_namespace_instances() {
        let a = NamespaceSet::full();
        let b = NamespaceSet::full();
        for k in ALL_KINDS {
            assert_ne!(a.id_of(k), b.id_of(k), "{k:?} shared!");
        }
    }

    #[test]
    fn partial_sets() {
        let ns = NamespaceSet::of(&[NamespaceKind::Pid, NamespaceKind::Mount]);
        assert!(ns.has(NamespaceKind::Pid));
        assert!(!ns.has(NamespaceKind::Network));
        assert_eq!(ns.len(), 2);
    }
}
