//! The control plane (§II "Cloud Services", §III): query lifecycle,
//! warehouse management, the *global* solver cache, the historical stats
//! framework, and the query-initialization pipeline whose latency Fig. 4
//! measures.

mod init;
mod plane;

pub use init::{InitPipeline, InitRequest, InitResult};
pub use plane::{ControlPlane, ControlPlaneConfig};
