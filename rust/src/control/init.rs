//! The query-initialization pipeline (§IV.A, Fig. 4):
//!
//!   solve (solver cache) → prepare env (environment cache: download /
//!   install / link) → sandbox creation → interpreter start.
//!
//! Caching configuration is explicit so the Fig. 4 bench can run the same
//! trace under {no caches, solver cache only, solver + env caches}.

use std::sync::Arc;

use anyhow::Result;

use crate::packages::{
    InitBreakdown, Installer, PackageSpec, Resolution, Solver, SolverCache,
};
use crate::util::clock::Clock;
use crate::warehouse::VirtualWarehouse;

/// Which §IV.A optimizations are active.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InitRequest {
    pub use_solver_cache: bool,
    pub use_env_cache: bool,
    /// Node index within the warehouse the query landed on.
    pub node: usize,
}

/// Outcome: the resolved closure plus the per-stage latency breakdown.
#[derive(Debug, Clone)]
pub struct InitResult {
    pub resolution: Arc<Resolution>,
    pub breakdown: InitBreakdown,
}

/// The initialization pipeline bound to a universe + global solver cache.
pub struct InitPipeline<'u> {
    pub solver: Solver<'u>,
    pub solver_cache: Arc<SolverCache>,
    pub installer: Installer,
}

impl<'u> InitPipeline<'u> {
    /// Run initialization for one query on `warehouse.nodes[req.node]`,
    /// charging elapsed stage time to `clock`.
    pub fn run(
        &self,
        specs: &[PackageSpec],
        warehouse: &mut VirtualWarehouse,
        req: InitRequest,
        clock: &dyn Clock,
    ) -> Result<InitResult> {
        let mut breakdown = InitBreakdown::default();

        // Stage 1: dependency solving, short-circuited by the global
        // solver cache.
        let (resolution, cache_hit) = if req.use_solver_cache {
            let (r, hit) = self.solver_cache.resolve(&self.solver, specs)?;
            (r, hit)
        } else {
            (Arc::new(self.solver.solve(&SolverCache::normalize(specs))?), false)
        };
        breakdown.solver_cache_hit = cache_hit;
        breakdown.solve_us = if cache_hit {
            // Metadata lookup only.
            500.0
        } else {
            self.installer.solve_cost_us(&resolution)
        };
        clock.sleep(std::time::Duration::from_nanos((breakdown.solve_us * 1e3) as u64));

        // Stage 2..n: environment preparation on the node.
        let node = &mut warehouse.nodes[req.node];
        if req.use_env_cache {
            self.installer.prepare_env(
                &resolution,
                &mut node.env_cache,
                clock,
                node.base_env_ready,
                &mut breakdown,
            );
        } else {
            // No environment cache: every query pays the full download +
            // install + link cost into a throwaway cache.
            let mut scratch = crate::packages::EnvironmentCache::new(u64::MAX / 2);
            self.installer.prepare_env(
                &resolution,
                &mut scratch,
                clock,
                node.base_env_ready,
                &mut breakdown,
            );
        }
        Ok(InitResult { resolution, breakdown })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::packages::{LatencyModel, PackageUniverse, Prefetcher};
    use crate::util::clock::SimClock;
    use crate::util::ids::WarehouseId;
    use crate::warehouse::WarehouseConfig;

    fn setup(u: &PackageUniverse) -> (InitPipeline<'_>, VirtualWarehouse, SimClock) {
        let pipeline = InitPipeline {
            solver: Solver::new(u),
            solver_cache: Arc::new(SolverCache::new()),
            installer: Installer::new(LatencyModel::default()),
        };
        let mut wh =
            VirtualWarehouse::provision(WarehouseId(1), WarehouseConfig::default());
        wh.warm_up(u, &Prefetcher::new(0, 0)); // base env only, no prefetch
        (pipeline, wh, SimClock::new())
    }

    #[test]
    fn cold_warm_hot_ordering() {
        let u = PackageUniverse::generate(200, 21);
        let (p, mut wh, clock) = setup(&u);
        let specs = vec![PackageSpec::any(u.by_name("pandas").unwrap())];
        let req = InitRequest { use_solver_cache: true, use_env_cache: true, node: 0 };

        let cold = p.run(&specs, &mut wh, req, &clock).unwrap();
        assert!(!cold.breakdown.solver_cache_hit);
        assert!(!cold.breakdown.env_cache_hit);

        let hot = p.run(&specs, &mut wh, req, &clock).unwrap();
        assert!(hot.breakdown.solver_cache_hit);
        assert!(hot.breakdown.env_cache_hit);
        assert!(
            hot.breakdown.total_us() < cold.breakdown.total_us() / 5.0,
            "hot {} vs cold {}",
            hot.breakdown.total_us(),
            cold.breakdown.total_us()
        );
    }

    #[test]
    fn disabling_caches_disables_hits() {
        let u = PackageUniverse::generate(200, 21);
        let (p, mut wh, clock) = setup(&u);
        let specs = vec![PackageSpec::any(0)];
        let req = InitRequest { use_solver_cache: false, use_env_cache: false, node: 0 };
        let a = p.run(&specs, &mut wh, req, &clock).unwrap();
        let b = p.run(&specs, &mut wh, req, &clock).unwrap();
        assert!(!b.breakdown.solver_cache_hit);
        assert!(!b.breakdown.env_cache_hit);
        // Both runs pay roughly the same full cost.
        let ratio = a.breakdown.total_us() / b.breakdown.total_us();
        assert!((0.5..2.0).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn solver_cache_shared_across_warehouse_nodes() {
        let u = PackageUniverse::generate(200, 21);
        let (p, mut wh, clock) = setup(&u);
        let specs = vec![PackageSpec::any(3)];
        let r0 = InitRequest { use_solver_cache: true, use_env_cache: true, node: 0 };
        let r1 = InitRequest { use_solver_cache: true, use_env_cache: true, node: 1 };
        p.run(&specs, &mut wh, r0, &clock).unwrap();
        let second = p.run(&specs, &mut wh, r1, &clock).unwrap();
        // Different node: env cache cold, but the *global* solver cache hits.
        assert!(second.breakdown.solver_cache_hit);
        assert!(!second.breakdown.env_cache_hit);
    }

    #[test]
    fn clock_advances_by_breakdown_total() {
        let u = PackageUniverse::generate(200, 21);
        let (p, mut wh, clock) = setup(&u);
        let specs = vec![PackageSpec::any(1)];
        let req = InitRequest { use_solver_cache: true, use_env_cache: true, node: 0 };
        let r = p.run(&specs, &mut wh, req, &clock).unwrap();
        let sim_us = clock.now_nanos() as f64 / 1e3;
        assert!(
            (sim_us - r.breakdown.total_us()).abs() < 1.0,
            "sim {sim_us} vs breakdown {}",
            r.breakdown.total_us()
        );
    }
}
