//! The control plane: owns warehouses, the global solver cache, the
//! historical stats framework, and end-to-end query orchestration.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::packages::{Installer, LatencyModel, PackageUniverse, Prefetcher, SolverCache};
use crate::scheduler::StatsFramework;
use crate::util::ids::{IdGen, WarehouseId};
use crate::warehouse::{VirtualWarehouse, WarehouseConfig};

/// Control-plane knobs.
#[derive(Debug, Clone)]
pub struct ControlPlaneConfig {
    pub latency: LatencyModel,
    pub prefetch_top_k: usize,
    pub prefetch_bytes: u64,
    pub stats_history: usize,
}

impl Default for ControlPlaneConfig {
    fn default() -> Self {
        Self {
            latency: LatencyModel::default(),
            prefetch_top_k: 32,
            prefetch_bytes: 8 << 30,
            stats_history: 20,
        }
    }
}

/// The "brain" (§II): one per deployment; warehouses hang off it.
pub struct ControlPlane {
    pub universe: Arc<PackageUniverse>,
    pub solver_cache: Arc<SolverCache>,
    pub stats: Arc<StatsFramework>,
    pub config: ControlPlaneConfig,
    warehouses: HashMap<WarehouseId, VirtualWarehouse>,
    by_name: HashMap<String, WarehouseId>,
    ids: IdGen,
}

impl ControlPlane {
    pub fn new(universe: Arc<PackageUniverse>, config: ControlPlaneConfig) -> Self {
        Self {
            universe,
            solver_cache: Arc::new(SolverCache::new()),
            stats: Arc::new(StatsFramework::new(config.stats_history)),
            config,
            warehouses: HashMap::new(),
            by_name: HashMap::new(),
            ids: IdGen::new(),
        }
    }

    /// Provision (and warm up) a warehouse.
    pub fn create_warehouse(&mut self, config: WarehouseConfig) -> WarehouseId {
        let id = WarehouseId(self.ids.next());
        let mut wh = VirtualWarehouse::provision(id, config.clone());
        wh.warm_up(
            &self.universe,
            &Prefetcher::new(self.config.prefetch_top_k, self.config.prefetch_bytes),
        );
        self.by_name.insert(config.name.clone(), id);
        self.warehouses.insert(id, wh);
        id
    }

    pub fn warehouse(&self, id: WarehouseId) -> Option<&VirtualWarehouse> {
        self.warehouses.get(&id)
    }

    pub fn warehouse_mut(&mut self, id: WarehouseId) -> Option<&mut VirtualWarehouse> {
        self.warehouses.get_mut(&id)
    }

    pub fn warehouse_by_name(&self, name: &str) -> Option<WarehouseId> {
        self.by_name.get(name).copied()
    }

    pub fn drop_warehouse(&mut self, id: WarehouseId) -> Result<()> {
        let wh = self
            .warehouses
            .remove(&id)
            .ok_or_else(|| anyhow!("unknown warehouse {id}"))?;
        self.by_name.remove(&wh.config.name);
        Ok(())
    }

    /// Build an init pipeline bound to this plane's caches.
    pub fn init_pipeline(&self) -> super::init::InitPipeline<'_> {
        super::init::InitPipeline {
            solver: crate::packages::Solver::new(&self.universe),
            solver_cache: self.solver_cache.clone(),
            installer: Installer::new(self.config.latency.clone()),
        }
    }

    pub fn warehouse_count(&self) -> usize {
        self.warehouses.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plane() -> ControlPlane {
        ControlPlane::new(
            Arc::new(PackageUniverse::generate(128, 5)),
            ControlPlaneConfig::default(),
        )
    }

    #[test]
    fn create_lookup_drop() {
        let mut cp = plane();
        let id = cp.create_warehouse(WarehouseConfig {
            name: "etl".into(),
            ..Default::default()
        });
        assert_eq!(cp.warehouse_by_name("etl"), Some(id));
        assert_eq!(cp.warehouse_count(), 1);
        // Warmed on provision.
        assert!(cp.warehouse(id).unwrap().nodes[0].base_env_ready);
        cp.drop_warehouse(id).unwrap();
        assert_eq!(cp.warehouse_count(), 0);
        assert!(cp.warehouse_by_name("etl").is_none());
        assert!(cp.drop_warehouse(id).is_err());
    }

    #[test]
    fn solver_cache_is_global_across_warehouses() {
        use crate::packages::PackageSpec;
        use crate::util::clock::SimClock;
        let mut cp = plane();
        let a = cp.create_warehouse(WarehouseConfig { name: "a".into(), ..Default::default() });
        let b = cp.create_warehouse(WarehouseConfig { name: "b".into(), ..Default::default() });
        let specs = vec![PackageSpec::any(2)];
        let clock = SimClock::new();
        let req = crate::control::InitRequest {
            use_solver_cache: true,
            use_env_cache: true,
            node: 0,
        };
        {
            let pipeline = cp.init_pipeline();
            let mut wh_a = VirtualWarehouse::provision(a, WarehouseConfig::default());
            pipeline.run(&specs, &mut wh_a, req, &clock).unwrap();
            let mut wh_b = VirtualWarehouse::provision(b, WarehouseConfig::default());
            let r = pipeline.run(&specs, &mut wh_b, req, &clock).unwrap();
            assert!(r.breakdown.solver_cache_hit, "global cache must hit across warehouses");
        }
        assert_eq!(cp.solver_cache.misses(), 1);
        assert_eq!(cp.solver_cache.hits(), 1);
    }
}
