//! XLA-backed vectorized UDFs: the bridge from the engine's vectorized
//! UDF interface (§III.A) to the AOT-compiled Pallas kernels (L1/L2).
//!
//! Each registered UDF marshals rowset columns into f32 literals, pads
//! the last batch up to the kernel's static shape, executes via PJRT, and
//! truncates the output — so callers see exact row counts while the
//! kernels keep fixed AOT shapes. Streaming statistics (min/max, Pearson
//! moments) are combined natively across batches, matching the L2
//! contract (`ref.pearson_moments` docs).

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::session::Session;
use crate::types::{DataType, RowSet};

use super::service::XlaService;

/// Geometry of the AOT artifacts (read from the manifest at runtime).
#[derive(Debug, Clone, Copy)]
pub struct KernelGeometry {
    pub batch_rows: usize,
    pub num_features: usize,
    pub num_classes: usize,
}

/// Read the kernel geometry from the manifest.
pub fn geometry(rt: &XlaService) -> Result<KernelGeometry> {
    let mm = rt
        .spec("minmax_stats")
        .ok_or_else(|| anyhow!("minmax_stats not in manifest"))?;
    let oh = rt
        .spec("one_hot")
        .ok_or_else(|| anyhow!("one_hot not in manifest"))?;
    Ok(KernelGeometry {
        batch_rows: mm.inputs[0].dims[0],
        num_features: mm.inputs[0].dims[1],
        num_classes: oh.outputs[0].dims[1],
    })
}

/// Marshal `count` rows of a single numeric column into a padded
/// (batch_rows × features) buffer by repeating the last row (padding rows
/// are sliced away after execution; repetition keeps min/max unbiased).
fn pad_tail(buf: &mut Vec<f32>, rows: usize, batch_rows: usize, width: usize) {
    debug_assert_eq!(buf.len(), rows * width);
    if rows == 0 {
        buf.resize(batch_rows * width, 0.0);
        return;
    }
    let last: Vec<f32> = buf[(rows - 1) * width..rows * width].to_vec();
    for _ in rows..batch_rows {
        buf.extend_from_slice(&last);
    }
}

/// Min-max scale one f64 column to [0,1] via the AOT kernels, streaming
/// in fixed-size batches: pass 1 combines per-batch stats kernels, pass 2
/// applies. Returns the scaled values.
///
/// PERF (EXPERIMENTS.md §Perf, L1 iteration 1): the column is *packed*
/// across all F feature lanes — each kernel call consumes B×F consecutive
/// elements instead of B elements in lane 0 — cutting PJRT dispatches by
/// F× (16×). The per-lane stats rows are combined natively (min of lane
/// mins / max of lane maxes), and the apply pass broadcasts the global
/// stats to every lane, so numerics are identical to the unpacked layout.
pub fn minmax_scale_column(rt: &XlaService, data: &[f64]) -> Result<Vec<f64>> {
    let geo = geometry(rt)?;
    let (b, f) = (geo.batch_rows, geo.num_features);
    let chunk = b * f;
    let n = data.len();

    // Pass 1: global min/max from packed stats kernels.
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    let mut off = 0;
    while off < n {
        let take = chunk.min(n - off);
        let mut buf: Vec<f32> = Vec::with_capacity(chunk);
        buf.extend(data[off..off + take].iter().map(|&v| v as f32));
        // Pad by repeating the last element: unbiased for min/max.
        let last = buf[take - 1];
        buf.resize(chunk, last);
        let out = rt.execute("minmax_stats", vec![buf])?;
        // Combine all lane mins / lane maxes.
        for lane in 0..f {
            lo = lo.min(out[0][lane]);
            hi = hi.max(out[0][f + lane]);
        }
        off += take;
    }

    // Pass 2: apply with the global stats broadcast to every lane.
    let mut stats = vec![0.0f32; 2 * f];
    for lane in 0..f {
        stats[lane] = lo;
        stats[f + lane] = hi;
    }
    let mut result = Vec::with_capacity(n);
    off = 0;
    while off < n {
        let take = chunk.min(n - off);
        let mut buf: Vec<f32> = Vec::with_capacity(chunk);
        buf.extend(data[off..off + take].iter().map(|&v| v as f32));
        buf.resize(chunk, 0.0);
        let out = rt.execute("minmax_apply", vec![buf, stats.clone()])?;
        result.extend(out[0][..take].iter().map(|&v| v as f64));
        off += take;
    }
    Ok(result)
}

/// One-hot encode an integer-coded column; returns row-major (n × C).
pub fn one_hot_column(rt: &XlaService, codes: &[f64]) -> Result<(Vec<f32>, usize)> {
    let geo = geometry(rt)?;
    let b = geo.batch_rows;
    let c = geo.num_classes;
    let n = codes.len();
    let mut out = Vec::with_capacity(n * c);
    let mut off = 0;
    while off < n {
        let take = b.min(n - off);
        let mut buf: Vec<f32> = codes[off..off + take].iter().map(|&v| v as f32).collect();
        pad_tail(&mut buf, take, b, 1);
        let res = rt.execute("one_hot", vec![buf])?;
        out.extend_from_slice(&res[0][..take * c]);
        off += take;
    }
    Ok((out, c))
}

/// Pearson correlation of up to F columns via streamed moment kernels
/// combined natively. Returns the (w × w) correlation matrix row-major.
pub fn pearson_columns(rt: &XlaService, columns: &[&[f64]]) -> Result<Vec<f64>> {
    let geo = geometry(rt)?;
    let (b, f) = (geo.batch_rows, geo.num_features);
    let w = columns.len();
    if w == 0 || w > f {
        return Err(anyhow!("pearson supports 1..={f} columns, got {w}"));
    }
    let n = columns[0].len();
    if columns.iter().any(|c| c.len() != n) {
        return Err(anyhow!("ragged columns"));
    }
    let mut xtx = vec![0.0f64; f * f];
    let mut colsum = vec![0.0f64; f];
    let mut off = 0;
    let mut rows_used = 0usize;
    // PERF (§Perf, L3 iteration 2): one reusable marshalling buffer per
    // call instead of a fresh zeroed Vec per chunk; columns are written
    // with per-column inner loops (sequential reads per source column).
    let mut buf = vec![0.0f32; b * f];
    while off < n {
        let take = b.min(n - off);
        // Zero-pad the tail: zero rows contribute nothing to moments, so
        // moments over `rows_used` rows stay exact.
        if take < b {
            buf.iter_mut().for_each(|v| *v = 0.0);
        }
        for (j, col) in columns.iter().enumerate() {
            let src = &col[off..off + take];
            for (i, &v) in src.iter().enumerate() {
                buf[i * f + j] = v as f32;
            }
        }
        let out = rt.execute("pearson_moments", vec![buf.clone()])?;
        for i in 0..f * f {
            xtx[i] += out[0][i] as f64;
        }
        for i in 0..f {
            colsum[i] += out[1][i] as f64;
        }
        rows_used += take;
        off += take;
    }
    // Finalize natively (the rust half of the streaming contract).
    let nf = rows_used as f64;
    let mut corr = vec![0.0f64; w * w];
    let mean: Vec<f64> = (0..w).map(|j| colsum[j] / nf).collect();
    let mut cov = vec![0.0f64; w * w];
    for a in 0..w {
        for bb in 0..w {
            cov[a * w + bb] = xtx[a * f + bb] / nf - mean[a] * mean[bb];
        }
    }
    let std: Vec<f64> = (0..w).map(|j| cov[j * w + j].max(0.0).sqrt()).collect();
    for a in 0..w {
        for bb in 0..w {
            corr[a * w + bb] = if a == bb {
                1.0
            } else if std[a] > 0.0 && std[bb] > 0.0 {
                cov[a * w + bb] / (std[a] * std[bb])
            } else {
                0.0
            };
        }
    }
    Ok(corr)
}

/// Register the XLA-backed vectorized UDFs on a session:
/// - `xla_minmax_scale(x)` — §V.B min-max scaling (77× case study);
/// - `xla_one_hot_idx(code)` — the hot index of the one-hot row (full
///   matrix callers use `one_hot_column` directly);
/// Pearson is a table-level statistic, exposed via `pearson_columns`.
pub fn register_xla_udfs(session: &Arc<Session>, rt: Arc<XlaService>) -> Result<()> {
    {
        let rt = rt.clone();
        session.register_vectorized_udf(
            "xla_minmax_scale",
            DataType::Float64,
            Arc::new(move |rows: &RowSet| {
                let data = rows.column(0).to_f32_vec()?;
                let data64: Vec<f64> = data.iter().map(|&v| v as f64).collect();
                minmax_scale_column(&rt, &data64)
            }),
        );
    }
    {
        let rt = rt.clone();
        session.register_vectorized_udf(
            "xla_one_hot_idx",
            DataType::Float64,
            Arc::new(move |rows: &RowSet| {
                let codes = rows.column(0).to_f32_vec()?;
                let codes64: Vec<f64> = codes.iter().map(|&v| v as f64).collect();
                let (mat, c) = one_hot_column(&rt, &codes64)?;
                Ok((0..codes64.len())
                    .map(|i| {
                        let row = &mat[i * c..(i + 1) * c];
                        row.iter()
                            .position(|&v| v == 1.0)
                            .map(|p| p as f64)
                            .unwrap_or(-1.0)
                    })
                    .collect())
            }),
        );
    }
    session.set_udf_packages("xla_minmax_scale", &["numpy", "scikit-learn"]);
    session.set_udf_packages("xla_one_hot_idx", &["numpy", "scikit-learn"]);
    Ok(())
}
