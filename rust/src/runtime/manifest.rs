//! Artifact manifest: shape/dtype metadata for each AOT-compiled kernel.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.txt` in a simple
//! line-oriented format (no serde available offline):
//!
//! ```text
//! kernel <name> <file>
//! input <name> <dtype> <d0>x<d1>x...
//! output <name> <dtype> <d0>x<d1>x...
//! end
//! ```

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

/// Logical tensor shape + dtype of a kernel input or output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorShape {
    pub name: String,
    pub dtype: String,
    pub dims: Vec<usize>,
}

impl TensorShape {
    pub fn elements(&self) -> usize {
        self.dims.iter().product()
    }
}

/// One AOT-compiled kernel: the HLO text file plus its I/O signature.
#[derive(Debug, Clone)]
pub struct KernelSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorShape>,
    pub outputs: Vec<TensorShape>,
}

/// The set of kernels shipped in an artifacts directory.
#[derive(Debug, Clone, Default)]
pub struct ArtifactManifest {
    pub kernels: Vec<KernelSpec>,
}

fn parse_shape(line: &str) -> Result<TensorShape> {
    // e.g. `input x f32 1024x16`
    let parts: Vec<&str> = line.split_whitespace().collect();
    if parts.len() != 4 {
        bail!("malformed shape line: {line:?}");
    }
    let dims = parts[3]
        .split('x')
        .map(|d| d.parse::<usize>().map_err(|e| anyhow!("bad dim {d}: {e}")))
        .collect::<Result<Vec<_>>>()?;
    Ok(TensorShape {
        name: parts[1].to_string(),
        dtype: parts[2].to_string(),
        dims,
    })
}

impl ArtifactManifest {
    pub fn parse(text: &str) -> Result<Self> {
        let mut kernels = Vec::new();
        let mut current: Option<KernelSpec> = None;
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let err = |msg: &str| anyhow!("manifest line {}: {msg}: {line:?}", lineno + 1);
            if let Some(rest) = line.strip_prefix("kernel ") {
                if current.is_some() {
                    bail!(err("nested kernel block"));
                }
                let parts: Vec<&str> = rest.split_whitespace().collect();
                if parts.len() != 2 {
                    bail!(err("expected `kernel <name> <file>`"));
                }
                current = Some(KernelSpec {
                    name: parts[0].to_string(),
                    file: parts[1].to_string(),
                    inputs: Vec::new(),
                    outputs: Vec::new(),
                });
            } else if line.starts_with("input ") {
                current
                    .as_mut()
                    .ok_or_else(|| err("input outside kernel block"))?
                    .inputs
                    .push(parse_shape(line)?);
            } else if line.starts_with("output ") {
                current
                    .as_mut()
                    .ok_or_else(|| err("output outside kernel block"))?
                    .outputs
                    .push(parse_shape(line)?);
            } else if line == "end" {
                let k = current.take().ok_or_else(|| err("end without kernel"))?;
                kernels.push(k);
            } else {
                bail!(err("unrecognized directive"));
            }
        }
        if current.is_some() {
            bail!("manifest ended inside a kernel block");
        }
        Ok(Self { kernels })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_round_trip() {
        let text = "\
# comment
kernel minmax_scale minmax_scale.hlo.txt
input x f32 1024x16
output y f32 1024x16
end
kernel pearson pearson.hlo.txt
input x f32 1024x16
output corr f32 16x16
end
";
        let m = ArtifactManifest::parse(text).unwrap();
        assert_eq!(m.kernels.len(), 2);
        assert_eq!(m.kernels[0].name, "minmax_scale");
        assert_eq!(m.kernels[0].inputs[0].dims, vec![1024, 16]);
        assert_eq!(m.kernels[0].inputs[0].elements(), 1024 * 16);
        assert_eq!(m.kernels[1].outputs[0].dims, vec![16, 16]);
    }

    #[test]
    fn rejects_malformed() {
        assert!(ArtifactManifest::parse("bogus line").is_err());
        assert!(ArtifactManifest::parse("kernel a f\ninput x f32 4\n").is_err());
        assert!(ArtifactManifest::parse("input x f32 4\nend\n").is_err());
        assert!(ArtifactManifest::parse("kernel a f\ninput x f32 4y4\nend\n").is_err());
    }
}
