//! XLA execution service: a dedicated thread owns the (non-`Send`) PJRT
//! client and serves execution requests over channels, so the rest of the
//! coordinator — interpreter pool threads included — can call kernels
//! through a `Send + Sync` handle. One service per node in a real
//! deployment; one per process here.

use std::path::Path;
use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use super::client::XlaRuntime;
use super::manifest::ArtifactManifest;

enum Req {
    Execute {
        kernel: String,
        inputs: Vec<Vec<f32>>,
        reply: mpsc::Sender<Result<Vec<Vec<f32>>>>,
    },
    Shutdown,
}

/// Thread-safe handle to the runtime thread.
pub struct XlaService {
    tx: mpsc::Sender<Req>,
    manifest: ArtifactManifest,
    handle: Option<JoinHandle<()>>,
}

impl XlaService {
    /// Start the service: the runtime (PJRT client + executable cache)
    /// lives entirely on the spawned thread.
    pub fn start(artifacts_dir: impl AsRef<Path>) -> Result<XlaService> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = ArtifactManifest::load(dir.join("manifest.txt"))?;
        let (tx, rx) = mpsc::channel::<Req>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name("xla-service".into())
            .spawn(move || {
                let runtime = match XlaRuntime::open(&dir) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Req::Shutdown => break,
                        Req::Execute { kernel, inputs, reply } => {
                            let res = runtime
                                .load(&kernel)
                                .and_then(|k| k.execute_f32(&inputs));
                            let _ = reply.send(res);
                        }
                    }
                }
            })
            .expect("spawn xla-service");
        ready_rx
            .recv()
            .map_err(|_| anyhow!("xla service thread died during startup"))??;
        Ok(XlaService { tx, manifest, handle: Some(handle) })
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    pub fn spec(&self, name: &str) -> Option<&super::manifest::KernelSpec> {
        self.manifest.kernels.iter().find(|k| k.name == name)
    }

    /// Execute a kernel by name (blocking; requests are serialized on the
    /// service thread — PJRT CPU parallelizes internally).
    pub fn execute(&self, kernel: &str, inputs: Vec<Vec<f32>>) -> Result<Vec<Vec<f32>>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Req::Execute { kernel: kernel.to_string(), inputs, reply })
            .map_err(|_| anyhow!("xla service is gone"))?;
        rx.recv().map_err(|_| anyhow!("xla service dropped the request"))?
    }
}

impl Drop for XlaService {
    fn drop(&mut self) {
        let _ = self.tx.send(Req::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
