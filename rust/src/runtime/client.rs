//! XLA PJRT client wrapper: compile HLO text once, execute many times.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

use super::manifest::{ArtifactManifest, KernelSpec};

/// A compiled XLA executable plus the shape metadata the engine needs to
/// marshal rowset columns in and out.
pub struct CompiledKernel {
    pub spec: KernelSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl CompiledKernel {
    /// Execute with f32 input buffers. Each input is a flat buffer whose
    /// logical shape is given by `spec.inputs[i]`. Returns the flat f32
    /// outputs in manifest order.
    pub fn execute_f32(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(anyhow!(
                "kernel {}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            ));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, shape) in inputs.iter().zip(&self.spec.inputs) {
            let expected: usize = shape.dims.iter().product();
            if buf.len() != expected {
                return Err(anyhow!(
                    "kernel {}: input buffer len {} != shape {:?}",
                    self.spec.name,
                    buf.len(),
                    shape.dims
                ));
            }
            let dims: Vec<i64> = shape.dims.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(buf).reshape(&dims)?;
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let mut root = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: the root is always a tuple.
        let parts = root.decompose_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for part in parts {
            out.push(part.to_vec::<f32>()?);
        }
        Ok(out)
    }
}

/// Runtime that owns a PJRT CPU client and a cache of compiled artifacts.
///
/// `XlaRuntime` is the only place the `xla` crate is touched; the rest of
/// the coordinator sees [`CompiledKernel`] handles. Compilation happens at
/// most once per artifact (keyed by kernel name), mirroring how Snowflake
/// compiles a query plan fragment once per warehouse.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    manifest: ArtifactManifest,
    cache: Mutex<HashMap<String, std::sync::Arc<CompiledKernel>>>,
}

impl XlaRuntime {
    /// Open the artifacts directory produced by `make artifacts`.
    pub fn open(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let artifacts_dir = artifacts_dir.as_ref().to_path_buf();
        let manifest_path = artifacts_dir.join("manifest.txt");
        let manifest = ArtifactManifest::load(&manifest_path)
            .with_context(|| format!("loading {}", manifest_path.display()))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Self {
            client,
            artifacts_dir,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Default artifacts location relative to the repo root, honoring
    /// `SNOWPARK_ARTIFACTS` for tests and examples run from other cwds.
    pub fn default_dir() -> PathBuf {
        if let Ok(dir) = std::env::var("SNOWPARK_ARTIFACTS") {
            return PathBuf::from(dir);
        }
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// True if an artifacts directory with a manifest exists at `dir`.
    pub fn available(dir: impl AsRef<Path>) -> bool {
        dir.as_ref().join("manifest.txt").is_file()
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    pub fn kernel_names(&self) -> Vec<String> {
        self.manifest.kernels.iter().map(|k| k.name.clone()).collect()
    }

    pub fn spec(&self, name: &str) -> Option<&KernelSpec> {
        self.manifest.kernels.iter().find(|k| k.name == name)
    }

    /// Load (compiling on first use) the kernel called `name`.
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<CompiledKernel>> {
        if let Some(k) = self.cache.lock().unwrap().get(name) {
            return Ok(k.clone());
        }
        let spec = self
            .spec(name)
            .ok_or_else(|| anyhow!("kernel {name} not in manifest"))?
            .clone();
        let path = self.artifacts_dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        let kernel = std::sync::Arc::new(CompiledKernel { spec, exe });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), kernel.clone());
        Ok(kernel)
    }

    /// Number of kernels compiled so far (for tests / metrics).
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}
