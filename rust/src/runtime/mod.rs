//! PJRT runtime: load AOT-compiled HLO text artifacts and execute them
//! from the Layer-3 hot path. Python is never on the request path — it
//! runs once at build time (`make artifacts`) to produce
//! `artifacts/*.hlo.txt`, which this module compiles with the XLA CPU
//! PJRT client and serves as vectorized-UDF executables.

mod client;
mod service;
pub mod kernels;
mod manifest;

pub use client::{CompiledKernel, XlaRuntime};
pub use service::XlaService;
pub use manifest::{ArtifactManifest, KernelSpec, TensorShape};
