//! Hand-rolled CLI argument parser (clap is unavailable offline).
//!
//! Supports `subcommand` dispatch, `--flag`, `--key value`, `--key=value`,
//! and positional arguments, with a generated usage string.

use std::collections::HashMap;

/// Parsed arguments: a subcommand, named options, boolean flags, and
/// positionals, in that structure.
#[derive(Debug, Default, Clone)]
pub struct ParsedArgs {
    pub subcommand: Option<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
    pub positionals: Vec<String>,
}

impl ParsedArgs {
    /// Parse from an iterator of args (excluding argv[0]).
    /// `known_flags` lists boolean flags (no value); everything else with a
    /// `--` prefix consumes the next token as its value.
    pub fn parse<I: IntoIterator<Item = String>>(args: I, known_flags: &[&str]) -> Result<Self, String> {
        let mut out = ParsedArgs::default();
        let mut iter = args.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    out.options
                        .insert(stripped[..eq].to_string(), stripped[eq + 1..].to_string());
                } else if known_flags.contains(&stripped) {
                    out.flags.push(stripped.to_string());
                } else {
                    let value = iter
                        .next()
                        .ok_or_else(|| format!("--{stripped} expects a value"))?;
                    out.options.insert(stripped.to_string(), value);
                }
            } else if out.subcommand.is_none() && out.positionals.is_empty() {
                out.subcommand = Some(arg);
            } else {
                out.positionals.push(arg);
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name} expects a number, got {v:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str], flags: &[&str]) -> ParsedArgs {
        ParsedArgs::parse(args.iter().map(|s| s.to_string()), flags).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(
            &["run-sql", "--warehouse", "etl", "--limit=10", "select 1"],
            &[],
        );
        assert_eq!(a.subcommand.as_deref(), Some("run-sql"));
        assert_eq!(a.get("warehouse"), Some("etl"));
        assert_eq!(a.get("limit"), Some("10"));
        assert_eq!(a.positionals, vec!["select 1"]);
    }

    #[test]
    fn flags_vs_options() {
        let a = parse(&["bench", "--verbose", "--seed", "42"], &["verbose"]);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.get_u64("seed", 0).unwrap(), 42);
    }

    #[test]
    fn typed_getters_with_defaults() {
        let a = parse(&["x"], &[]);
        assert_eq!(a.get_usize("n", 7).unwrap(), 7);
        assert_eq!(a.get_f64("r", 0.5).unwrap(), 0.5);
        let a = parse(&["x", "--n", "abc"], &[]);
        assert!(a.get_usize("n", 7).is_err());
    }

    #[test]
    fn missing_value_is_error() {
        let err = ParsedArgs::parse(["--key".to_string()], &[]).unwrap_err();
        assert!(err.contains("expects a value"));
    }
}
