//! Wall and virtual clocks.
//!
//! Latency-model experiments (Fig. 4, Fig. 5, the CTC cost model) run on a
//! [`SimClock`] so results are deterministic and independent of the host;
//! compute experiments (Fig. 6, Fidelity) use [`WallClock`] and real
//! threads. Code under test takes `&dyn Clock` (or the enum) so the same
//! pipeline serves both.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Time source abstraction. `now_nanos` is monotonic from an arbitrary
/// epoch; `sleep` advances the clock (virtually or really).
pub trait Clock: Send + Sync {
    fn now_nanos(&self) -> u64;
    fn sleep(&self, d: Duration);

    fn now(&self) -> Duration {
        Duration::from_nanos(self.now_nanos())
    }
}

/// Real time, anchored at construction.
pub struct WallClock {
    start: Instant,
}

impl WallClock {
    pub fn new() -> Self {
        Self { start: Instant::now() }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now_nanos(&self) -> u64 {
        self.start.elapsed().as_nanos() as u64
    }

    fn sleep(&self, d: Duration) {
        std::thread::sleep(d);
    }
}

/// Deterministic virtual time. `sleep` advances the counter instantly —
/// a whole "night of ETL jobs" simulates in milliseconds of real time.
#[derive(Clone, Default)]
pub struct SimClock {
    nanos: Arc<AtomicU64>,
}

impl SimClock {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn advance(&self, d: Duration) {
        self.nanos.fetch_add(d.as_nanos() as u64, Ordering::SeqCst);
    }

    pub fn set_nanos(&self, nanos: u64) {
        self.nanos.store(nanos, Ordering::SeqCst);
    }
}

impl Clock for SimClock {
    fn now_nanos(&self) -> u64 {
        self.nanos.load(Ordering::SeqCst)
    }

    fn sleep(&self, d: Duration) {
        self.advance(d);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_clock_advances_on_sleep() {
        let c = SimClock::new();
        assert_eq!(c.now_nanos(), 0);
        c.sleep(Duration::from_millis(5));
        assert_eq!(c.now_nanos(), 5_000_000);
        c.advance(Duration::from_secs(1));
        assert_eq!(c.now(), Duration::from_nanos(1_005_000_000));
    }

    #[test]
    fn sim_clock_clones_share_time() {
        let a = SimClock::new();
        let b = a.clone();
        a.advance(Duration::from_secs(2));
        assert_eq!(b.now_nanos(), 2_000_000_000);
    }

    #[test]
    fn wall_clock_is_monotonic() {
        let c = WallClock::new();
        let t0 = c.now_nanos();
        let t1 = c.now_nanos();
        assert!(t1 >= t0);
    }
}
