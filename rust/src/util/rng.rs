//! Deterministic PRNG + the distributions the simulators need.
//!
//! SplitMix64 core (Steele et al.): tiny state, excellent mixing, and —
//! critically for reproducible experiments — the same stream on every
//! platform. All workload generators take an explicit seed so every bench
//! row in EXPERIMENTS.md can be regenerated bit-for-bit.

/// SplitMix64 pseudo-random generator.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Derive an independent child stream (stable, collision-resistant).
    pub fn fork(&mut self, salt: u64) -> Rng {
        Rng::new(self.next_u64() ^ salt.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform integer in [0, n). Uses Lemire's rejection-free reduction;
    /// the modulo bias is < 2^-32 for every n the simulators use.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_inclusive(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    pub fn bool(&mut self, p_true: f64) -> bool {
        self.f64() < p_true
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Log-normal with the given *underlying* normal mu/sigma. The init
    /// latency and memory traces use this — production latency tails are
    /// classically log-normal-ish.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with mean `mean` (inter-arrival times).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.f64()).max(1e-300).ln()
    }

    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }

    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len() as u64) as usize]
    }
}

/// Zipf(N, s) sampler — package popularity and retail-item skew are both
/// Zipf-shaped in the paper's domain. Precomputes the CDF once; sampling
/// is a binary search (O(log N)).
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in &mut cdf {
            *v /= total;
        }
        Self { cdf }
    }

    /// Sample a rank in [0, N); rank 0 is the most popular item.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self.cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    pub fn n(&self) -> usize {
        self.cdf.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forked_streams_diverge() {
        let mut root = Rng::new(7);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_mean_is_half() {
        let mut rng = Rng::new(11);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Rng::new(17);
        let n = 50_000;
        let mean = (0..n).map(|_| rng.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 4.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn zipf_rank0_most_popular() {
        let mut rng = Rng::new(19);
        let z = Zipf::new(100, 1.1);
        let mut counts = vec![0usize; 100];
        for _ in 0..20_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[0] > counts[99] * 5);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(23);
        let mut v: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // overwhelmingly likely
    }

    #[test]
    fn lognormal_is_positive() {
        let mut rng = Rng::new(29);
        for _ in 0..1000 {
            assert!(rng.lognormal(0.0, 2.0) > 0.0);
        }
    }
}
