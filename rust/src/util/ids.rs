//! Strongly-typed identifiers used across the coordinator.

macro_rules! id_type {
    ($(#[$doc:meta])* $name:ident, $prefix:expr) => {
        $(#[$doc])*
        #[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
        pub struct $name(pub u64);

        impl std::fmt::Display for $name {
            fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                write!(f, "{}-{}", $prefix, self.0)
            }
        }

        impl From<u64> for $name {
            fn from(v: u64) -> Self {
                Self(v)
            }
        }
    };
}

id_type!(
    /// A query submitted to the control plane.
    QueryId, "q"
);
id_type!(
    /// A virtual warehouse.
    WarehouseId, "wh"
);
id_type!(
    /// A node (VM) inside a virtual warehouse.
    NodeId, "node"
);
id_type!(
    /// A (simulated) Python interpreter process in a sandbox.
    ProcId, "proc"
);
id_type!(
    /// A customer account (solver cache is global *across* accounts).
    AccountId, "acct"
);

/// Monotonic id allocator (thread-safe).
#[derive(Debug, Default)]
pub struct IdGen {
    next: std::sync::atomic::AtomicU64,
}

impl IdGen {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn next(&self) -> u64 {
        self.next.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_order() {
        assert_eq!(QueryId(3).to_string(), "q-3");
        assert_eq!(WarehouseId(0).to_string(), "wh-0");
        assert!(QueryId(1) < QueryId(2));
    }

    #[test]
    fn idgen_monotonic() {
        let g = IdGen::new();
        assert_eq!(g.next(), 0);
        assert_eq!(g.next(), 1);
        assert_eq!(g.next(), 2);
    }
}
