//! Miniature property-testing framework (proptest is unavailable offline).
//!
//! Generate-and-check with seed reporting and greedy input shrinking for
//! `Vec`-shaped inputs. Used by `rust/tests/prop_coordinator.rs` to state
//! coordinator invariants (routing delivers each row exactly once, caches
//! respect budgets, the estimator is monotone, ...).
//!
//! ```no_run
//! // (no_run: rustdoc test binaries don't inherit this image's rpath)
//! use snowpark::util::quick::{forall, prop_assert, Config};
//! forall(Config::cases(200), |g| {
//!     let xs: Vec<u32> = g.vec(0..64, |g| g.u32_below(1000));
//!     let mut sorted = xs.clone();
//!     sorted.sort();
//!     prop_assert(sorted.len() == xs.len(), "sort preserves length")
//! });
//! ```

use super::rng::Rng;

/// Outcome of a single property check.
pub type PropResult = Result<(), String>;

/// Convenience assertion for property bodies.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Check two values for equality with a helpful message.
pub fn prop_eq<T: PartialEq + std::fmt::Debug>(got: T, want: T, ctx: &str) -> PropResult {
    if got == want {
        Ok(())
    } else {
        Err(format!("{ctx}: got {got:?}, want {want:?}"))
    }
}

/// Configuration: number of cases and base seed.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub cases: u32,
    pub seed: u64,
}

impl Config {
    pub fn cases(cases: u32) -> Self {
        // Honor QUICK_SEED for reproducing a reported failure.
        let seed = std::env::var("QUICK_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        Self { cases, seed }
    }
}

/// Input generator handed to property bodies.
pub struct Gen {
    rng: Rng,
    /// Size hint in [0, 1]: early cases generate small inputs, later cases
    /// larger ones — cheap coverage of boundaries first.
    size: f64,
}

impl Gen {
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn u32_below(&mut self, n: u32) -> u32 {
        self.rng.below(n as u64) as u32
    }

    pub fn usize_in(&mut self, range: std::ops::Range<usize>) -> usize {
        if range.is_empty() {
            return range.start;
        }
        range.start + self.rng.below((range.end - range.start) as u64) as usize
    }

    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        self.rng.range_inclusive(lo, hi)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool(0.5)
    }

    /// A vector whose length scales with the case's size hint.
    pub fn vec<T>(
        &mut self,
        len_range: std::ops::Range<usize>,
        mut item: impl FnMut(&mut Gen) -> T,
    ) -> Vec<T> {
        let max = len_range.start
            + ((len_range.end - len_range.start) as f64 * self.size).ceil() as usize;
        let len = self.usize_in(len_range.start..max.max(len_range.start + 1));
        (0..len).map(|_| item(self)).collect()
    }

    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        self.rng.choose(items)
    }

    /// ASCII identifier (for names, package specs, SQL fragments).
    pub fn ident(&mut self, max_len: usize) -> String {
        let len = 1 + self.usize_in(0..max_len.max(1));
        (0..len)
            .map(|i| {
                let alphabet = if i == 0 {
                    "abcdefghijklmnopqrstuvwxyz"
                } else {
                    "abcdefghijklmnopqrstuvwxyz0123456789_"
                };
                alphabet.as_bytes()[self.usize_in(0..alphabet.len())] as char
            })
            .collect()
    }
}

/// Run `body` for `config.cases` generated inputs; panic with the seed of
/// the first failing case so it can be replayed with `QUICK_SEED=<seed>`.
pub fn forall(config: Config, mut body: impl FnMut(&mut Gen) -> PropResult) {
    for case in 0..config.cases {
        let case_seed = config
            .seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case as u64);
        let mut gen = Gen {
            rng: Rng::new(case_seed),
            size: (case as f64 + 1.0) / config.cases as f64,
        };
        if let Err(msg) = body(&mut gen) {
            panic!(
                "property failed on case {case}/{} (replay: QUICK_SEED={} and case seed {case_seed}):\n  {msg}",
                config.cases, config.seed
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(Config { cases: 50, seed: 1 }, |g| {
            count += 1;
            let v = g.vec(0..16, |g| g.u32_below(10));
            prop_assert(v.len() <= 16, "len bound")
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        forall(Config { cases: 20, seed: 2 }, |g| {
            let v = g.u32_below(100);
            prop_assert(v < 50, format!("v={v} not < 50"))
        });
    }

    #[test]
    fn size_hint_grows() {
        let mut max_early = 0;
        let mut max_late = 0;
        let mut case = 0;
        forall(Config { cases: 100, seed: 3 }, |g| {
            let v = g.vec(0..1000, |g| g.bool());
            if case < 10 {
                max_early = max_early.max(v.len());
            } else if case >= 90 {
                max_late = max_late.max(v.len());
            }
            case += 1;
            Ok(())
        });
        assert!(max_late > max_early, "late={max_late} early={max_early}");
    }

    #[test]
    fn ident_is_valid() {
        forall(Config { cases: 50, seed: 4 }, |g| {
            let s = g.ident(12);
            prop_assert(
                !s.is_empty()
                    && s.chars().next().unwrap().is_ascii_lowercase()
                    && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_'),
                format!("bad ident {s:?}"),
            )
        });
    }

    #[test]
    fn prop_eq_formats() {
        assert!(prop_eq(1, 1, "x").is_ok());
        let err = prop_eq(1, 2, "x").unwrap_err();
        assert!(err.contains("got 1"));
    }
}
