//! Hybrid sparse/dense HyperLogLog distinct-count sketch (std-only).
//!
//! Registration-time statistics (`engine::stats`) used to count every
//! column's exact NDV through a `HashSet<u64>` — O(distinct) memory per
//! column, which is exactly the cost a wide high-cardinality table
//! cannot pay. [`Hll`] keeps the best of both regimes:
//!
//! - **Sparse** (≤ [`Hll::SPARSE_CAP`] distinct hashes): an exact
//!   `HashSet<u64>`, so small and medium columns — including every
//!   differential-test and explain-golden fixture — report *exact*
//!   counts, byte-for-byte identical to the old code's estimates.
//! - **Dense** (beyond the cap): the set collapses into `m = 2^P`
//!   one-byte registers holding max leading-zero ranks, the classic
//!   Flajolet–Fuss–Gandouet–Meunier estimator with the small-range
//!   linear-counting correction. Memory is a flat 4 KiB per column no
//!   matter how many distinct values stream in; the relative error is
//!   ≈ 1.04/√m ≈ 1.6 %.
//!
//! Inputs are 64-bit hashes the callers already have (the stats pass
//! feeds raw bit-casts — `v as u64`, `f.to_bits()` — and the join-build
//! gate feeds `EncodedKeys::hash`). Those raw casts are *not* uniformly
//! distributed, so [`Hll::insert`] finalizes every input through the
//! SplitMix64 mixer before taking register index and rank bits.

use std::collections::HashSet;

/// Register-index bits: `m = 2^P = 4096` registers in dense mode.
const P: u32 = 12;
/// Dense register count.
const M: usize = 1 << P;

/// SplitMix64 finalizer: a full-avalanche bijection on `u64`, so exact
/// sparse counts are preserved (distinct inputs stay distinct) while
/// dense mode sees uniformly distributed bits.
fn mix(mut h: u64) -> u64 {
    h = h.wrapping_add(0x9e37_79b9_7f4a_7c15);
    h = (h ^ (h >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    h = (h ^ (h >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    h ^ (h >> 31)
}

/// The sketch. `Default`/[`Hll::new`] start empty in sparse mode.
#[derive(Debug, Clone)]
pub struct Hll {
    /// Exact mixed-hash set while small; drained on densify.
    sparse: Option<HashSet<u64>>,
    /// Dense registers, allocated only on densify.
    registers: Option<Box<[u8; M]>>,
}

impl Default for Hll {
    fn default() -> Self {
        Self::new()
    }
}

impl Hll {
    /// Distinct-hash count at which sparse mode collapses into dense
    /// registers. Up to here `estimate()` is exact.
    pub const SPARSE_CAP: usize = 4096;

    /// Empty sketch (sparse mode).
    pub fn new() -> Self {
        Self { sparse: Some(HashSet::new()), registers: None }
    }

    /// Insert one 64-bit hash. Callers pass whatever 64-bit identity
    /// they already have for the value; mixing happens here.
    pub fn insert(&mut self, raw: u64) {
        let h = mix(raw);
        if let Some(sparse) = &mut self.sparse {
            sparse.insert(h);
            if sparse.len() > Self::SPARSE_CAP {
                let drained = std::mem::take(sparse);
                self.sparse = None;
                let mut regs = Box::new([0u8; M]);
                for v in drained {
                    Self::bump(&mut regs, v);
                }
                self.registers = Some(regs);
            }
            return;
        }
        Self::bump(self.registers.as_mut().expect("dense registers"), h);
    }

    /// Update one dense register from a mixed hash: top `P` bits pick
    /// the register, the rank is leading zeros of the remaining bits,
    /// plus one.
    fn bump(regs: &mut [u8; M], h: u64) {
        let idx = (h >> (64 - P)) as usize;
        let rest = h << P;
        let rank = (rest.leading_zeros().min(64 - P) + 1) as u8;
        if regs[idx] < rank {
            regs[idx] = rank;
        }
    }

    /// Number of distinct hashes inserted so far: exact in sparse mode,
    /// the bias-corrected harmonic-mean estimate in dense mode.
    pub fn estimate(&self) -> f64 {
        if let Some(sparse) = &self.sparse {
            return sparse.len() as f64;
        }
        let regs = self.registers.as_ref().expect("dense registers");
        let m = M as f64;
        // alpha_m for m ≥ 128.
        let alpha = 0.7213 / (1.0 + 1.079 / m);
        let mut inv_sum = 0.0f64;
        let mut zeros = 0u32;
        for &r in regs.iter() {
            // r ≤ 64 − P + 1 = 53, so the shift never overflows.
            inv_sum += 1.0 / (1u64 << r) as f64;
            if r == 0 {
                zeros += 1;
            }
        }
        let raw = alpha * m * m / inv_sum;
        if raw <= 2.5 * m && zeros > 0 {
            // Small-range correction: linear counting over empty
            // registers is more accurate below ~2.5m.
            m * (m / zeros as f64).ln()
        } else {
            raw
        }
    }

    /// Is the sketch still in exact sparse mode?
    pub fn is_exact(&self) -> bool {
        self.sparse.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn sparse_mode_is_exact() {
        let mut h = Hll::new();
        for v in 0..1000u64 {
            h.insert(v);
            h.insert(v); // duplicates never count
        }
        assert!(h.is_exact());
        assert_eq!(h.estimate(), 1000.0);
    }

    #[test]
    fn empty_estimates_zero() {
        let h = Hll::new();
        assert_eq!(h.estimate(), 0.0);
    }

    #[test]
    fn dense_mode_stays_within_relative_error() {
        // 1.04/sqrt(4096) ≈ 1.6% standard error; assert a generous 6%.
        let mut rng = Rng::new(0xD15C0);
        for &n in &[10_000u64, 100_000, 1_000_000] {
            let mut h = Hll::new();
            // Distinct draws: mix a counter through the RNG stream so
            // inputs aren't sequential (sequential also works — insert
            // mixes — but this exercises arbitrary identities).
            let base = rng.next_u64();
            for i in 0..n {
                h.insert(base ^ (i.wrapping_mul(0x2545_F491_4F6C_DD1D)));
            }
            assert!(!h.is_exact());
            let est = h.estimate();
            let err = (est - n as f64).abs() / n as f64;
            assert!(err < 0.06, "n={n} est={est} err={err}");
        }
    }

    #[test]
    fn densify_preserves_continuity_across_the_cap() {
        // Crossing SPARSE_CAP must not discontinuously jump: the dense
        // estimate right after densify stays close to the exact count.
        let mut h = Hll::new();
        for v in 0..(Hll::SPARSE_CAP as u64 + 1) {
            h.insert(v);
        }
        assert!(!h.is_exact());
        let n = (Hll::SPARSE_CAP + 1) as f64;
        let err = (h.estimate() - n).abs() / n;
        assert!(err < 0.06, "est={} err={err}", h.estimate());
    }
}
