//! Foundation utilities built from scratch for the offline environment
//! (no rand / serde / clap / criterion / proptest crates are available):
//! deterministic PRNG + distributions, wall/virtual clocks, percentile
//! histograms, a hybrid-exact HyperLogLog distinct-count sketch, a
//! byte-budgeted LRU, a TOML-subset config parser, a CLI argument
//! parser, and a miniature property-testing framework.

pub mod cli;
pub mod clock;
pub mod histogram;
pub mod hll;
pub mod ids;
pub mod lru;
pub mod quick;
pub mod rng;
pub mod toml;
