//! Percentile statistics for latency/memory reporting.
//!
//! Two flavours:
//! - [`Sampled`]: keeps every observation; exact percentiles. Used by the
//!   bench harness (thousands of points, exactness matters for tables).
//! - [`LogHistogram`]: fixed-size log-bucketed histogram (HdrHistogram-
//!   style, ~1.04x relative error) for request-path metrics where keeping
//!   every sample would itself be a hot-loop allocation.

/// Exact percentile estimator that stores all samples.
#[derive(Debug, Clone, Default)]
pub struct Sampled {
    values: Vec<f64>,
    sorted: bool,
}

impl Sampled {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, v: f64) {
        self.values.push(v);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return 0.0;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.values.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.values.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Exact percentile (nearest-rank with linear interpolation).
    /// `p` in [0, 100].
    pub fn percentile(&mut self, p: f64) -> f64 {
        assert!(!self.values.is_empty(), "percentile of empty histogram");
        if !self.sorted {
            self.values
                .sort_by(|a, b| a.partial_cmp(b).expect("NaN in histogram"));
            self.sorted = true;
        }
        let n = self.values.len();
        if n == 1 {
            return self.values[0];
        }
        let rank = (p / 100.0) * (n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.values[lo] * (1.0 - frac) + self.values[hi] * frac
    }

    pub fn sum(&self) -> f64 {
        self.values.iter().sum()
    }
}

/// Log-bucketed histogram over u64 values (e.g. nanoseconds).
/// 64 decades × `SUB` sub-buckets; relative error ≤ 1/SUB.
#[derive(Debug, Clone)]
pub struct LogHistogram {
    counts: Vec<u64>,
    total: u64,
    sum: u128,
}

const SUB: usize = 32; // sub-buckets per power of two => ≤3.2% rel. error

impl Default for LogHistogram {
    fn default() -> Self {
        Self { counts: vec![0; 64 * SUB], total: 0, sum: 0 }
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    fn bucket(v: u64) -> usize {
        if v < SUB as u64 {
            return v as usize;
        }
        let msb = 63 - v.leading_zeros() as usize;
        let shift = msb - SUB.trailing_zeros() as usize;
        let sub = ((v >> shift) as usize) & (SUB - 1);
        (shift + 1) * SUB + sub
    }

    #[inline]
    fn bucket_value(idx: usize) -> u64 {
        let decade = idx / SUB;
        let sub = idx % SUB;
        if decade == 0 {
            return sub as u64;
        }
        let shift = decade - 1;
        ((SUB + sub) as u64) << shift
    }

    pub fn record(&mut self, v: u64) {
        self.counts[Self::bucket(v)] += 1;
        self.total += 1;
        self.sum += v as u128;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.sum as f64 / self.total as f64
    }

    /// Approximate percentile; `p` in [0, 100].
    pub fn percentile(&self, p: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = ((p / 100.0) * self.total as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Self::bucket_value(i);
            }
        }
        Self::bucket_value(self.counts.len() - 1)
    }

    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
    }
}

/// Equi-width histogram over a fixed `[min, max]` value domain.
///
/// The planner's `StatsStore` builds one per numeric column at table
/// registration and asks it for range selectivities (`P(v < x)`,
/// `P(a ≤ v ≤ b)`) when costing predicates. Buckets assume a uniform
/// distribution *within* a bucket (the classic equi-width estimate), so
/// the answer is exact at bucket boundaries and linearly interpolated
/// inside them.
#[derive(Debug, Clone)]
pub struct EquiWidth {
    min: f64,
    max: f64,
    counts: Vec<u64>,
    total: u64,
}

impl EquiWidth {
    /// Default bucket count used by the stats store.
    pub const BUCKETS: usize = 32;

    /// Build a histogram over `[min, max]` with `buckets` equal-width
    /// bins. A degenerate domain (`min == max`, or non-finite bounds)
    /// collapses to a single bucket.
    pub fn new(min: f64, max: f64, buckets: usize) -> Self {
        let buckets = buckets.max(1);
        if !(min.is_finite() && max.is_finite()) || min >= max {
            return Self { min, max: min, counts: vec![0; 1], total: 0 };
        }
        Self { min, max, counts: vec![0; buckets], total: 0 }
    }

    #[inline]
    fn bucket_of(&self, v: f64) -> usize {
        if self.max <= self.min {
            return 0;
        }
        let width = (self.max - self.min) / self.counts.len() as f64;
        let idx = ((v - self.min) / width) as usize;
        idx.min(self.counts.len() - 1)
    }

    /// Record one observation. Values outside `[min, max]` clamp to the
    /// boundary buckets.
    pub fn record(&mut self, v: f64) {
        if !v.is_finite() {
            return;
        }
        let idx = self.bucket_of(v.clamp(self.min, self.max));
        self.counts[idx] += 1;
        self.total += 1;
    }

    /// Number of recorded observations.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Estimated fraction of recorded values strictly below `x`
    /// (uniform-within-bucket interpolation), in `[0, 1]`.
    pub fn fraction_below(&self, x: f64) -> f64 {
        if self.total == 0 {
            return 0.5;
        }
        if x <= self.min {
            return 0.0;
        }
        if x >= self.max {
            return 1.0;
        }
        if self.max <= self.min {
            // Degenerate single-valued domain: all mass at `min`.
            return if x > self.min { 1.0 } else { 0.0 };
        }
        let width = (self.max - self.min) / self.counts.len() as f64;
        let idx = self.bucket_of(x);
        let mut below = 0u64;
        for &c in &self.counts[..idx] {
            below += c;
        }
        let lo = self.min + idx as f64 * width;
        let frac_in = ((x - lo) / width).clamp(0.0, 1.0);
        (below as f64 + frac_in * self.counts[idx] as f64) / self.total as f64
    }

    /// Estimated fraction of recorded values in `[lo, hi]`, in `[0, 1]`.
    pub fn fraction_between(&self, lo: f64, hi: f64) -> f64 {
        if hi < lo {
            return 0.0;
        }
        (self.fraction_below(hi) - self.fraction_below(lo)).clamp(0.0, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampled_exact_percentiles() {
        let mut h = Sampled::new();
        for v in 1..=100 {
            h.record(v as f64);
        }
        assert_eq!(h.percentile(0.0), 1.0);
        assert_eq!(h.percentile(100.0), 100.0);
        assert!((h.percentile(50.0) - 50.5).abs() < 1e-9);
        assert!((h.percentile(90.0) - 90.1).abs() < 1e-9);
        assert!((h.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn sampled_single_value() {
        let mut h = Sampled::new();
        h.record(7.0);
        assert_eq!(h.percentile(50.0), 7.0);
        assert_eq!(h.percentile(99.0), 7.0);
    }

    #[test]
    fn sampled_interleaved_record_and_query() {
        let mut h = Sampled::new();
        h.record(10.0);
        h.record(20.0);
        assert_eq!(h.percentile(100.0), 20.0);
        h.record(30.0); // must re-sort
        assert_eq!(h.percentile(100.0), 30.0);
    }

    #[test]
    fn log_histogram_small_values_exact() {
        let mut h = LogHistogram::new();
        for v in 0..SUB as u64 {
            h.record(v);
        }
        assert_eq!(h.percentile(0.0), 0);
        assert_eq!(h.percentile(100.0), SUB as u64 - 1);
    }

    #[test]
    fn log_histogram_relative_error_bounded() {
        let mut h = LogHistogram::new();
        let vals: Vec<u64> = (0..10_000).map(|i| 1000 + i * 173).collect();
        for &v in &vals {
            h.record(v);
        }
        let mut sorted = vals.clone();
        sorted.sort();
        for &p in &[50.0, 75.0, 90.0, 95.0, 99.0] {
            let exact = sorted[((p / 100.0) * (sorted.len() - 1) as f64) as usize] as f64;
            let approx = h.percentile(p) as f64;
            let rel = (approx - exact).abs() / exact;
            assert!(rel < 0.05, "p{p}: exact={exact} approx={approx} rel={rel}");
        }
    }

    #[test]
    fn log_histogram_merge() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        for v in 0..1000 {
            a.record(v);
            b.record(v + 1000);
        }
        a.merge(&b);
        assert_eq!(a.count(), 2000);
        let p50 = a.percentile(50.0);
        assert!((900..1100).contains(&p50), "p50={p50}");
    }

    #[test]
    fn equi_width_uniform_fractions() {
        let mut h = EquiWidth::new(0.0, 100.0, EquiWidth::BUCKETS);
        for i in 0..10_000 {
            h.record(i as f64 % 100.0);
        }
        assert_eq!(h.count(), 10_000);
        assert!((h.fraction_below(50.0) - 0.5).abs() < 0.02);
        assert!((h.fraction_below(2.0) - 0.02).abs() < 0.02);
        assert_eq!(h.fraction_below(-1.0), 0.0);
        assert_eq!(h.fraction_below(1000.0), 1.0);
        assert!((h.fraction_between(25.0, 75.0) - 0.5).abs() < 0.03);
    }

    #[test]
    fn equi_width_degenerate_domain() {
        let mut h = EquiWidth::new(7.0, 7.0, 32);
        h.record(7.0);
        h.record(7.0);
        assert_eq!(h.count(), 2);
        assert_eq!(h.fraction_below(7.0), 0.0);
        assert_eq!(h.fraction_below(8.0), 1.0);
    }

    #[test]
    fn equi_width_empty_is_noncommittal() {
        let h = EquiWidth::new(0.0, 1.0, 8);
        assert_eq!(h.fraction_below(0.5), 0.5);
    }

    #[test]
    fn log_histogram_huge_values() {
        let mut h = LogHistogram::new();
        h.record(u64::MAX / 2);
        h.record(3);
        assert_eq!(h.count(), 2);
        assert!(h.percentile(100.0) > u64::MAX / 4);
    }
}
