//! TOML-subset parser for the config system (serde/toml unavailable
//! offline).
//!
//! Supported: `[table]` and `[table.sub]` headers, `key = value` with
//! string / integer / float / boolean / homogeneous-array values, `#`
//! comments, and bare or quoted keys. Unsupported TOML (dates, inline
//! tables, multiline strings, arrays-of-tables) is rejected with a line
//! number — the config surface in `config/` only needs the subset.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed scalar or array value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Array(a) => Some(a),
            _ => None,
        }
    }
}

impl fmt::Display for TomlValue {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TomlValue::Str(s) => write!(f, "{s:?}"),
            TomlValue::Int(i) => write!(f, "{i}"),
            TomlValue::Float(x) => write!(f, "{x}"),
            TomlValue::Bool(b) => write!(f, "{b}"),
            TomlValue::Array(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
        }
    }
}

/// Flat document: keys are dotted paths (`table.sub.key`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TomlDoc {
    entries: BTreeMap<String, TomlValue>,
}

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml parse error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for TomlError {}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<Self, TomlError> {
        let mut doc = TomlDoc::default();
        let mut prefix = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            let err = |message: String| TomlError { line: lineno + 1, message };
            if line.is_empty() {
                continue;
            }
            if let Some(header) = line.strip_prefix('[') {
                let header = header
                    .strip_suffix(']')
                    .ok_or_else(|| err("unterminated table header".into()))?
                    .trim();
                if header.is_empty() || header.starts_with('[') {
                    return Err(err(format!("unsupported table header {line:?}")));
                }
                validate_key_path(header).map_err(|m| err(m))?;
                prefix = header.to_string();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| err(format!("expected key = value, got {line:?}")))?;
            let key = line[..eq].trim().trim_matches('"');
            if key.is_empty() {
                return Err(err("empty key".into()));
            }
            validate_key_path(key).map_err(|m| err(m))?;
            let value = parse_value(line[eq + 1..].trim()).map_err(|m| err(m))?;
            let full = if prefix.is_empty() {
                key.to_string()
            } else {
                format!("{prefix}.{key}")
            };
            if doc.entries.insert(full.clone(), value).is_some() {
                return Err(err(format!("duplicate key {full:?}")));
            }
        }
        Ok(doc)
    }

    pub fn get(&self, path: &str) -> Option<&TomlValue> {
        self.entries.get(path)
    }

    pub fn str(&self, path: &str) -> Option<&str> {
        self.get(path).and_then(TomlValue::as_str)
    }

    pub fn int(&self, path: &str) -> Option<i64> {
        self.get(path).and_then(TomlValue::as_int)
    }

    pub fn float(&self, path: &str) -> Option<f64> {
        self.get(path).and_then(TomlValue::as_float)
    }

    pub fn bool(&self, path: &str) -> Option<bool> {
        self.get(path).and_then(TomlValue::as_bool)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.entries.keys()
    }

    /// All keys under a dotted prefix (for enumerating e.g. warehouses).
    pub fn keys_under<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = &'a String> + 'a {
        self.entries
            .keys()
            .filter(move |k| k.starts_with(prefix) && k[prefix.len()..].starts_with('.'))
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

fn strip_comment(line: &str) -> &str {
    // A `#` outside of a quoted string starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn validate_key_path(path: &str) -> Result<(), String> {
    for part in path.split('.') {
        let part = part.trim().trim_matches('"');
        if part.is_empty()
            || !part
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
        {
            return Err(format!("invalid key component {part:?}"));
        }
    }
    Ok(())
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("missing value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| format!("unterminated string {s:?}"))?;
        if inner.contains('"') {
            return Err(format!("embedded quote in {s:?} (escapes unsupported)"));
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| format!("unterminated array {s:?}"))?
            .trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(Vec::new()));
        }
        let items = split_array_items(inner)?;
        return Ok(TomlValue::Array(
            items
                .into_iter()
                .map(|i| parse_value(i.trim()))
                .collect::<Result<Vec<_>, _>>()?,
        ));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    let cleaned = s.replace('_', "");
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value {s:?}"))
}

fn split_array_items(s: &str) -> Result<Vec<&str>, String> {
    let mut items = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.checked_sub(1).ok_or("unbalanced ]")?,
            ',' if !in_str && depth == 0 => {
                items.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if in_str {
        return Err("unterminated string in array".into());
    }
    items.push(&s[start..]);
    Ok(items)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_tables() {
        let doc = TomlDoc::parse(
            r#"
# top comment
name = "prod"
workers = 8
ratio = 0.75
debug = false

[warehouse.etl]
nodes = 4
memory_gib = 64
"#,
        )
        .unwrap();
        assert_eq!(doc.str("name"), Some("prod"));
        assert_eq!(doc.int("workers"), Some(8));
        assert_eq!(doc.float("ratio"), Some(0.75));
        assert_eq!(doc.bool("debug"), Some(false));
        assert_eq!(doc.int("warehouse.etl.nodes"), Some(4));
        assert_eq!(doc.int("warehouse.etl.memory_gib"), Some(64));
    }

    #[test]
    fn arrays() {
        let doc = TomlDoc::parse(r#"pkgs = ["numpy", "pandas"] # inline comment"#).unwrap();
        let arr = doc.get("pkgs").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].as_str(), Some("numpy"));
        let doc = TomlDoc::parse("xs = [1, 2, 3]").unwrap();
        assert_eq!(doc.get("xs").unwrap().as_array().unwrap().len(), 3);
        let doc = TomlDoc::parse("xs = []").unwrap();
        assert!(doc.get("xs").unwrap().as_array().unwrap().is_empty());
    }

    #[test]
    fn int_as_float_coercion() {
        let doc = TomlDoc::parse("f = 3").unwrap();
        assert_eq!(doc.float("f"), Some(3.0));
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = TomlDoc::parse(r##"s = "a#b""##).unwrap();
        assert_eq!(doc.str("s"), Some("a#b"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = TomlDoc::parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(err.line, 2);
        let err = TomlDoc::parse("x = \"unterminated").unwrap_err();
        assert_eq!(err.line, 1);
        let err = TomlDoc::parse("[t]\nx = 1\n[t2\ny = 2").unwrap_err();
        assert_eq!(err.line, 3);
    }

    #[test]
    fn duplicate_keys_rejected() {
        assert!(TomlDoc::parse("a = 1\na = 2").is_err());
        // Same key in different tables is fine.
        assert!(TomlDoc::parse("[t1]\na = 1\n[t2]\na = 2").is_ok());
    }

    #[test]
    fn keys_under_prefix() {
        let doc = TomlDoc::parse("other = 3\n[wh.a]\nn = 1\n[wh.b]\nn = 2").unwrap();
        let keys: Vec<_> = doc.keys_under("wh").collect();
        assert_eq!(keys.len(), 2);
    }

    #[test]
    fn underscored_numbers() {
        let doc = TomlDoc::parse("big = 1_000_000").unwrap();
        assert_eq!(doc.int("big"), Some(1_000_000));
    }
}
