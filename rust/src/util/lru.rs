//! Byte-budgeted LRU cache.
//!
//! Backs the environment cache (§IV.A): entries carry an explicit byte
//! weight (installed package size), eviction is strictly
//! least-recently-used, and the cache never exceeds its capacity — an
//! invariant the property tests in `rust/tests/prop_coordinator.rs` hammer.

use std::collections::HashMap;
use std::hash::Hash;

#[derive(Debug)]
struct Entry<V> {
    value: V,
    bytes: u64,
    stamp: u64,
}

/// LRU keyed by `K`, weighted in bytes.
#[derive(Debug)]
pub struct LruCache<K: Eq + Hash + Clone, V> {
    map: HashMap<K, Entry<V>>,
    capacity_bytes: u64,
    used_bytes: u64,
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl<K: Eq + Hash + Clone, V> LruCache<K, V> {
    pub fn new(capacity_bytes: u64) -> Self {
        Self {
            map: HashMap::new(),
            capacity_bytes,
            used_bytes: 0,
            tick: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    fn touch(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    /// Look up, bumping recency on hit.
    pub fn get(&mut self, key: &K) -> Option<&V> {
        let stamp = self.touch();
        match self.map.get_mut(key) {
            Some(e) => {
                e.stamp = stamp;
                self.hits += 1;
                Some(&e.value)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Peek without recency bump or hit accounting (metrics, tests).
    pub fn peek(&self, key: &K) -> Option<&V> {
        self.map.get(key).map(|e| &e.value)
    }

    pub fn contains(&self, key: &K) -> bool {
        self.map.contains_key(key)
    }

    /// Insert (replacing any previous entry), then evict LRU entries until
    /// within budget. An entry larger than the whole budget is rejected
    /// (returns false) — matching "don't cache what can never fit".
    pub fn insert(&mut self, key: K, value: V, bytes: u64) -> bool {
        if bytes > self.capacity_bytes {
            return false;
        }
        let stamp = self.touch();
        if let Some(old) = self.map.insert(key, Entry { value, bytes, stamp }) {
            self.used_bytes -= old.bytes;
        }
        self.used_bytes += bytes;
        self.evict_to_fit();
        true
    }

    pub fn remove(&mut self, key: &K) -> Option<V> {
        self.map.remove(key).map(|e| {
            self.used_bytes -= e.bytes;
            e.value
        })
    }

    fn evict_to_fit(&mut self) {
        while self.used_bytes > self.capacity_bytes {
            // O(n) scan; caches hold at most a few thousand entries and
            // eviction is off the hot path (insert-after-install).
            let victim = self
                .map
                .iter()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(k, _)| k.clone())
                .expect("used_bytes > 0 implies non-empty");
            let e = self.map.remove(&victim).unwrap();
            self.used_bytes -= e.bytes;
            self.evictions += 1;
        }
    }

    /// Drop everything (warehouse VM recycle, §IV.A: "the environment
    /// cache gets reset when the virtual warehouse machines are recycled").
    pub fn clear(&mut self) {
        self.map.clear();
        self.used_bytes = 0;
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn used_bytes(&self) -> u64 {
        self.used_bytes
    }

    pub fn capacity_bytes(&self) -> u64 {
        self.capacity_bytes
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &K> {
        self.map.keys()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_hit_miss() {
        let mut c: LruCache<&str, u32> = LruCache::new(100);
        assert!(c.get(&"a").is_none());
        c.insert("a", 1, 10);
        assert_eq!(c.get(&"a"), Some(&1));
        assert_eq!(c.hits(), 1);
        assert_eq!(c.misses(), 1);
        assert!((c.hit_rate() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut c: LruCache<u32, u32> = LruCache::new(30);
        c.insert(1, 1, 10);
        c.insert(2, 2, 10);
        c.insert(3, 3, 10);
        c.get(&1); // 1 is now most recent; 2 is LRU
        c.insert(4, 4, 10);
        assert!(c.contains(&1));
        assert!(!c.contains(&2), "2 should have been evicted");
        assert!(c.contains(&3));
        assert!(c.contains(&4));
        assert_eq!(c.evictions(), 1);
    }

    #[test]
    fn never_exceeds_capacity() {
        let mut c: LruCache<u32, ()> = LruCache::new(55);
        for i in 0..100 {
            c.insert(i, (), 7);
            assert!(c.used_bytes() <= 55, "used={}", c.used_bytes());
        }
        assert_eq!(c.len(), 7); // 7 * 7 = 49 <= 55 < 56
    }

    #[test]
    fn oversized_entry_rejected() {
        let mut c: LruCache<u32, ()> = LruCache::new(10);
        assert!(!c.insert(1, (), 11));
        assert!(c.is_empty());
        assert!(c.insert(2, (), 10));
    }

    #[test]
    fn replace_updates_bytes() {
        let mut c: LruCache<u32, u32> = LruCache::new(100);
        c.insert(1, 10, 40);
        c.insert(1, 20, 60);
        assert_eq!(c.used_bytes(), 60);
        assert_eq!(c.get(&1), Some(&20));
    }

    #[test]
    fn clear_resets() {
        let mut c: LruCache<u32, u32> = LruCache::new(100);
        c.insert(1, 1, 50);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.used_bytes(), 0);
        assert!(c.get(&1).is_none());
    }

    #[test]
    fn remove_returns_value() {
        let mut c: LruCache<u32, String> = LruCache::new(100);
        c.insert(1, "x".into(), 10);
        assert_eq!(c.remove(&1), Some("x".into()));
        assert_eq!(c.used_bytes(), 0);
        assert_eq!(c.remove(&1), None);
    }
}
