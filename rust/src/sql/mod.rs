//! Minimal SQL layer: the substrate the DataFrame API emits into
//! (§III.A: "The API layer takes Python DataFrame operations, and emits
//! corresponding SQL statements to execute in Snowflake").
//!
//! Pipeline: [`lexer`] → [`parser`] → [`ast`] → `engine::planner`.

pub mod ast;
pub mod lexer;
pub mod parser;

pub use ast::{BinaryOp, Expr, JoinKind, OrderKey, Query, SelectItem, TableRef, UnaryOp};
pub use lexer::{tokenize, Token};
pub use parser::parse_query;
