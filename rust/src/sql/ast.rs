//! SQL abstract syntax tree.

use crate::types::Value;

/// Scalar expression.
#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    Literal(Value),
    /// Column reference (already lowercased unless quoted).
    Column(String),
    Unary {
        op: UnaryOp,
        expr: Box<Expr>,
    },
    Binary {
        op: BinaryOp,
        left: Box<Expr>,
        right: Box<Expr>,
    },
    /// Function call — builtin scalar, aggregate, or UDF/UDAF; classified
    /// at plan time. `COUNT(*)` is `Func{name: "count", args: [Star]}`.
    Func {
        name: String,
        args: Vec<Expr>,
    },
    /// `*` inside a function call (COUNT(*)) or the select list.
    Star,
    IsNull {
        expr: Box<Expr>,
        negated: bool,
    },
    InList {
        expr: Box<Expr>,
        list: Vec<Expr>,
        negated: bool,
    },
    Between {
        expr: Box<Expr>,
        low: Box<Expr>,
        high: Box<Expr>,
        negated: bool,
    },
    Case {
        /// WHEN cond THEN value pairs.
        branches: Vec<(Expr, Expr)>,
        else_value: Option<Box<Expr>>,
    },
}

impl Expr {
    pub fn col(name: &str) -> Expr {
        Expr::Column(name.to_string())
    }

    pub fn lit(v: Value) -> Expr {
        Expr::Literal(v)
    }

    /// Does this expression (transitively) contain a call to any function
    /// in `names`? Used by the planner for aggregate detection.
    pub fn contains_func(&self, pred: &dyn Fn(&str) -> bool) -> bool {
        match self {
            Expr::Func { name, args } => {
                pred(name) || args.iter().any(|a| a.contains_func(pred))
            }
            Expr::Unary { expr, .. } => expr.contains_func(pred),
            Expr::Binary { left, right, .. } => {
                left.contains_func(pred) || right.contains_func(pred)
            }
            Expr::IsNull { expr, .. } => expr.contains_func(pred),
            Expr::InList { expr, list, .. } => {
                expr.contains_func(pred) || list.iter().any(|e| e.contains_func(pred))
            }
            Expr::Between { expr, low, high, .. } => {
                expr.contains_func(pred) || low.contains_func(pred) || high.contains_func(pred)
            }
            Expr::Case { branches, else_value } => {
                branches
                    .iter()
                    .any(|(c, v)| c.contains_func(pred) || v.contains_func(pred))
                    || else_value.as_ref().map_or(false, |e| e.contains_func(pred))
            }
            _ => false,
        }
    }

    /// Column names referenced by this expression.
    pub fn referenced_columns(&self, out: &mut Vec<String>) {
        match self {
            Expr::Column(c) => out.push(c.clone()),
            Expr::Unary { expr, .. } => expr.referenced_columns(out),
            Expr::Binary { left, right, .. } => {
                left.referenced_columns(out);
                right.referenced_columns(out);
            }
            Expr::Func { args, .. } => {
                for a in args {
                    a.referenced_columns(out);
                }
            }
            Expr::IsNull { expr, .. } => expr.referenced_columns(out),
            Expr::InList { expr, list, .. } => {
                expr.referenced_columns(out);
                for e in list {
                    e.referenced_columns(out);
                }
            }
            Expr::Between { expr, low, high, .. } => {
                expr.referenced_columns(out);
                low.referenced_columns(out);
                high.referenced_columns(out);
            }
            Expr::Case { branches, else_value } => {
                for (c, v) in branches {
                    c.referenced_columns(out);
                    v.referenced_columns(out);
                }
                if let Some(e) = else_value {
                    e.referenced_columns(out);
                }
            }
            Expr::Literal(_) | Expr::Star => {}
        }
    }

    /// Render back to SQL text (the DataFrame API builds Expr trees and
    /// emits SQL through this).
    pub fn to_sql(&self) -> String {
        match self {
            Expr::Literal(Value::Str(s)) => format!("'{}'", s.replace('\'', "''")),
            Expr::Literal(v) => v.to_string(),
            Expr::Column(c) => c.clone(),
            Expr::Star => "*".to_string(),
            Expr::Unary { op, expr } => match op {
                UnaryOp::Neg => format!("(-{})", expr.to_sql()),
                UnaryOp::Not => format!("(NOT {})", expr.to_sql()),
            },
            Expr::Binary { op, left, right } => {
                format!("({} {} {})", left.to_sql(), op.sql(), right.to_sql())
            }
            Expr::Func { name, args } => {
                let args: Vec<String> = args.iter().map(Expr::to_sql).collect();
                format!("{}({})", name, args.join(", "))
            }
            Expr::IsNull { expr, negated } => format!(
                "({} IS{} NULL)",
                expr.to_sql(),
                if *negated { " NOT" } else { "" }
            ),
            Expr::InList { expr, list, negated } => {
                let items: Vec<String> = list.iter().map(Expr::to_sql).collect();
                format!(
                    "({}{} IN ({}))",
                    expr.to_sql(),
                    if *negated { " NOT" } else { "" },
                    items.join(", ")
                )
            }
            Expr::Between { expr, low, high, negated } => format!(
                "({}{} BETWEEN {} AND {})",
                expr.to_sql(),
                if *negated { " NOT" } else { "" },
                low.to_sql(),
                high.to_sql()
            ),
            Expr::Case { branches, else_value } => {
                let mut s = String::from("CASE");
                for (c, v) in branches {
                    s.push_str(&format!(" WHEN {} THEN {}", c.to_sql(), v.to_sql()));
                }
                if let Some(e) = else_value {
                    s.push_str(&format!(" ELSE {}", e.to_sql()));
                }
                s.push_str(" END");
                s
            }
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UnaryOp {
    Neg,
    Not,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinaryOp {
    Add,
    Sub,
    Mul,
    Div,
    Mod,
    Eq,
    NotEq,
    Lt,
    LtEq,
    Gt,
    GtEq,
    And,
    Or,
    Concat,
}

impl BinaryOp {
    pub fn sql(&self) -> &'static str {
        match self {
            BinaryOp::Add => "+",
            BinaryOp::Sub => "-",
            BinaryOp::Mul => "*",
            BinaryOp::Div => "/",
            BinaryOp::Mod => "%",
            BinaryOp::Eq => "=",
            BinaryOp::NotEq => "<>",
            BinaryOp::Lt => "<",
            BinaryOp::LtEq => "<=",
            BinaryOp::Gt => ">",
            BinaryOp::GtEq => ">=",
            BinaryOp::And => "AND",
            BinaryOp::Or => "OR",
            BinaryOp::Concat => "||",
        }
    }
}

/// One item in the select list.
#[derive(Debug, Clone, PartialEq)]
pub enum SelectItem {
    /// `*`
    Wildcard,
    /// `expr [AS alias]`
    Expr { expr: Expr, alias: Option<String> },
}

/// Join type (the engine implements inner and left outer).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JoinKind {
    Inner,
    Left,
}

/// A table reference in FROM.
#[derive(Debug, Clone, PartialEq)]
pub enum TableRef {
    /// Named table in the catalog.
    Table { name: String, alias: Option<String> },
    /// `(SELECT ...) alias`
    Subquery { query: Box<Query>, alias: Option<String> },
    /// `TABLE(udtf(args...))` — table function (UDTF) invocation.
    TableFunc {
        name: String,
        args: Vec<Expr>,
        alias: Option<String>,
    },
}

/// ORDER BY key.
#[derive(Debug, Clone, PartialEq)]
pub struct OrderKey {
    pub expr: Expr,
    pub descending: bool,
}

/// A parsed SELECT query.
#[derive(Debug, Clone, PartialEq)]
pub struct Query {
    pub select: Vec<SelectItem>,
    pub from: Option<TableRef>,
    pub joins: Vec<(JoinKind, TableRef, Expr)>,
    pub where_clause: Option<Expr>,
    pub group_by: Vec<Expr>,
    pub having: Option<Expr>,
    pub order_by: Vec<OrderKey>,
    pub limit: Option<usize>,
}

impl Query {
    /// Render back to SQL (round-trip property-tested in the parser).
    pub fn to_sql(&self) -> String {
        let mut s = String::from("SELECT ");
        let items: Vec<String> = self
            .select
            .iter()
            .map(|i| match i {
                SelectItem::Wildcard => "*".to_string(),
                SelectItem::Expr { expr, alias } => match alias {
                    Some(a) => format!("{} AS {}", expr.to_sql(), a),
                    None => expr.to_sql(),
                },
            })
            .collect();
        s.push_str(&items.join(", "));
        if let Some(from) = &self.from {
            s.push_str(" FROM ");
            s.push_str(&table_ref_sql(from));
        }
        for (kind, t, on) in &self.joins {
            s.push_str(match kind {
                JoinKind::Inner => " JOIN ",
                JoinKind::Left => " LEFT JOIN ",
            });
            s.push_str(&table_ref_sql(t));
            s.push_str(" ON ");
            s.push_str(&on.to_sql());
        }
        if let Some(w) = &self.where_clause {
            s.push_str(" WHERE ");
            s.push_str(&w.to_sql());
        }
        if !self.group_by.is_empty() {
            s.push_str(" GROUP BY ");
            let g: Vec<String> = self.group_by.iter().map(Expr::to_sql).collect();
            s.push_str(&g.join(", "));
        }
        if let Some(h) = &self.having {
            s.push_str(" HAVING ");
            s.push_str(&h.to_sql());
        }
        if !self.order_by.is_empty() {
            s.push_str(" ORDER BY ");
            let o: Vec<String> = self
                .order_by
                .iter()
                .map(|k| {
                    format!(
                        "{}{}",
                        k.expr.to_sql(),
                        if k.descending { " DESC" } else { "" }
                    )
                })
                .collect();
            s.push_str(&o.join(", "));
        }
        if let Some(n) = self.limit {
            s.push_str(&format!(" LIMIT {n}"));
        }
        s
    }
}

fn table_ref_sql(t: &TableRef) -> String {
    match t {
        TableRef::Table { name, alias } => match alias {
            Some(a) => format!("{name} {a}"),
            None => name.clone(),
        },
        TableRef::Subquery { query, alias } => match alias {
            Some(a) => format!("({}) {a}", query.to_sql()),
            None => format!("({})", query.to_sql()),
        },
        TableRef::TableFunc { name, args, alias } => {
            let args: Vec<String> = args.iter().map(Expr::to_sql).collect();
            let base = format!("TABLE({}({}))", name, args.join(", "));
            match alias {
                Some(a) => format!("{base} {a}"),
                None => base,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expr_helpers() {
        let e = Expr::Binary {
            op: BinaryOp::Add,
            left: Box::new(Expr::col("a")),
            right: Box::new(Expr::lit(Value::Int(1))),
        };
        assert_eq!(e.to_sql(), "(a + 1)");
        let mut cols = Vec::new();
        e.referenced_columns(&mut cols);
        assert_eq!(cols, vec!["a"]);
    }

    #[test]
    fn contains_func_transitive() {
        let e = Expr::Binary {
            op: BinaryOp::Mul,
            left: Box::new(Expr::Func {
                name: "sum".into(),
                args: vec![Expr::col("x")],
            }),
            right: Box::new(Expr::lit(Value::Int(2))),
        };
        assert!(e.contains_func(&|n| n == "sum"));
        assert!(!e.contains_func(&|n| n == "avg"));
    }

    #[test]
    fn string_literals_escape() {
        let e = Expr::lit(Value::Str("it's".into()));
        assert_eq!(e.to_sql(), "'it''s'");
    }
}
