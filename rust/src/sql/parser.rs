//! Recursive-descent SQL parser with precedence-climbing expressions.

use anyhow::{bail, Result};

use super::ast::*;
use super::lexer::{tokenize, Token};
use crate::types::Value;

/// Parse one SELECT query.
pub fn parse_query(sql: &str) -> Result<Query> {
    let tokens = tokenize(sql)?;
    let mut p = Parser { tokens, pos: 0 };
    let q = p.query()?;
    if p.pos != p.tokens.len() {
        bail!("trailing tokens after query: {:?}", &p.tokens[p.pos..]);
    }
    Ok(q)
}

struct Parser {
    tokens: Vec<Token>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> Option<&Token> {
        self.tokens.get(self.pos)
    }

    fn next(&mut self) -> Option<Token> {
        let t = self.tokens.get(self.pos).cloned();
        if t.is_some() {
            self.pos += 1;
        }
        t
    }

    fn eat_keyword(&mut self, kw: &str) -> bool {
        if let Some(Token::Ident(w)) = self.peek() {
            if w == kw {
                self.pos += 1;
                return true;
            }
        }
        false
    }

    fn peek_keyword(&self, kw: &str) -> bool {
        matches!(self.peek(), Some(Token::Ident(w)) if w == kw)
    }

    fn expect_keyword(&mut self, kw: &str) -> Result<()> {
        if self.eat_keyword(kw) {
            Ok(())
        } else {
            bail!("expected {kw:?}, found {:?}", self.peek())
        }
    }

    fn expect(&mut self, tok: &Token) -> Result<()> {
        match self.next() {
            Some(t) if &t == tok => Ok(()),
            other => bail!("expected {tok:?}, found {other:?}"),
        }
    }

    fn ident(&mut self) -> Result<String> {
        match self.next() {
            Some(Token::Ident(s)) => Ok(s),
            Some(Token::QuotedIdent(s)) => Ok(s),
            other => bail!("expected identifier, found {other:?}"),
        }
    }

    fn query(&mut self) -> Result<Query> {
        self.expect_keyword("select")?;
        let select = self.select_list()?;

        let from = if self.eat_keyword("from") {
            Some(self.table_ref()?)
        } else {
            None
        };

        let mut joins = Vec::new();
        loop {
            let kind = if self.peek_keyword("join") || self.peek_keyword("inner") {
                self.eat_keyword("inner");
                self.expect_keyword("join")?;
                JoinKind::Inner
            } else if self.peek_keyword("left") {
                self.eat_keyword("left");
                self.eat_keyword("outer");
                self.expect_keyword("join")?;
                JoinKind::Left
            } else {
                break;
            };
            let table = self.table_ref()?;
            self.expect_keyword("on")?;
            let on = self.expr(0)?;
            joins.push((kind, table, on));
        }

        let where_clause = if self.eat_keyword("where") {
            Some(self.expr(0)?)
        } else {
            None
        };

        let mut group_by = Vec::new();
        if self.eat_keyword("group") {
            self.expect_keyword("by")?;
            loop {
                group_by.push(self.expr(0)?);
                if !self.eat_comma() {
                    break;
                }
            }
        }

        let having = if self.eat_keyword("having") {
            Some(self.expr(0)?)
        } else {
            None
        };

        let mut order_by = Vec::new();
        if self.eat_keyword("order") {
            self.expect_keyword("by")?;
            loop {
                let expr = self.expr(0)?;
                let descending = if self.eat_keyword("desc") {
                    true
                } else {
                    self.eat_keyword("asc");
                    false
                };
                order_by.push(OrderKey { expr, descending });
                if !self.eat_comma() {
                    break;
                }
            }
        }

        let limit = if self.eat_keyword("limit") {
            match self.next() {
                Some(Token::Int(n)) if n >= 0 => Some(n as usize),
                other => bail!("LIMIT expects a non-negative integer, found {other:?}"),
            }
        } else {
            None
        };

        Ok(Query {
            select,
            from,
            joins,
            where_clause,
            group_by,
            having,
            order_by,
            limit,
        })
    }

    fn eat_comma(&mut self) -> bool {
        if self.peek() == Some(&Token::Comma) {
            self.pos += 1;
            true
        } else {
            false
        }
    }

    fn select_list(&mut self) -> Result<Vec<SelectItem>> {
        let mut items = Vec::new();
        loop {
            if self.peek() == Some(&Token::Star) {
                self.pos += 1;
                items.push(SelectItem::Wildcard);
            } else {
                let expr = self.expr(0)?;
                let alias = if self.eat_keyword("as") {
                    Some(self.ident()?)
                } else if let Some(Token::Ident(w)) = self.peek() {
                    // Bare alias, unless it's a clause keyword.
                    const CLAUSES: &[&str] = &[
                        "from", "where", "group", "having", "order", "limit", "join",
                        "inner", "left", "on",
                    ];
                    if CLAUSES.contains(&w.as_str()) {
                        None
                    } else {
                        Some(self.ident()?)
                    }
                } else {
                    None
                };
                items.push(SelectItem::Expr { expr, alias });
            }
            if !self.eat_comma() {
                break;
            }
        }
        Ok(items)
    }

    fn table_ref(&mut self) -> Result<TableRef> {
        if self.peek() == Some(&Token::LParen) {
            self.pos += 1;
            let query = self.query()?;
            self.expect(&Token::RParen)?;
            let alias = self.table_alias()?;
            return Ok(TableRef::Subquery { query: Box::new(query), alias });
        }
        if self.peek_keyword("table") {
            // TABLE(udtf(args...))
            self.pos += 1;
            self.expect(&Token::LParen)?;
            let name = self.ident()?;
            self.expect(&Token::LParen)?;
            let mut args = Vec::new();
            if self.peek() != Some(&Token::RParen) {
                loop {
                    args.push(self.expr(0)?);
                    if !self.eat_comma() {
                        break;
                    }
                }
            }
            self.expect(&Token::RParen)?;
            self.expect(&Token::RParen)?;
            let alias = self.table_alias()?;
            return Ok(TableRef::TableFunc { name, args, alias });
        }
        let name = self.ident()?;
        let alias = self.table_alias()?;
        Ok(TableRef::Table { name, alias })
    }

    fn table_alias(&mut self) -> Result<Option<String>> {
        if self.eat_keyword("as") {
            return Ok(Some(self.ident()?));
        }
        if let Some(Token::Ident(w)) = self.peek() {
            const CLAUSES: &[&str] = &[
                "where", "group", "having", "order", "limit", "join", "inner", "left", "on",
            ];
            if !CLAUSES.contains(&w.as_str()) {
                return Ok(Some(self.ident()?));
            }
        }
        Ok(None)
    }

    /// Precedence-climbing expression parser.
    /// Levels: OR(1) < AND(2) < NOT(3) < cmp/IS/IN/BETWEEN(4) < ||(5)
    ///         < +-(6) < */%(7) < unary-(8).
    fn expr(&mut self, min_prec: u8) -> Result<Expr> {
        let mut lhs = self.prefix()?;
        loop {
            let (prec, op) = match self.peek() {
                Some(Token::Ident(w)) if w == "or" => (1u8, Some(BinaryOp::Or)),
                Some(Token::Ident(w)) if w == "and" => (2, Some(BinaryOp::And)),
                Some(Token::Ident(w)) if w == "is" => (4, None),
                Some(Token::Ident(w)) if w == "in" => (4, None),
                Some(Token::Ident(w)) if w == "between" => (4, None),
                Some(Token::Ident(w)) if w == "not" => (4, None),
                Some(Token::Eq) => (4, Some(BinaryOp::Eq)),
                Some(Token::NotEq) => (4, Some(BinaryOp::NotEq)),
                Some(Token::Lt) => (4, Some(BinaryOp::Lt)),
                Some(Token::LtEq) => (4, Some(BinaryOp::LtEq)),
                Some(Token::Gt) => (4, Some(BinaryOp::Gt)),
                Some(Token::GtEq) => (4, Some(BinaryOp::GtEq)),
                Some(Token::Concat) => (5, Some(BinaryOp::Concat)),
                Some(Token::Plus) => (6, Some(BinaryOp::Add)),
                Some(Token::Minus) => (6, Some(BinaryOp::Sub)),
                Some(Token::Star) => (7, Some(BinaryOp::Mul)),
                Some(Token::Slash) => (7, Some(BinaryOp::Div)),
                Some(Token::Percent) => (7, Some(BinaryOp::Mod)),
                _ => break,
            };
            if prec < min_prec {
                break;
            }
            match op {
                Some(op) => {
                    self.pos += 1;
                    let rhs = self.expr(prec + 1)?;
                    lhs = Expr::Binary { op, left: Box::new(lhs), right: Box::new(rhs) };
                }
                None => {
                    // IS [NOT] NULL / [NOT] IN / [NOT] BETWEEN
                    if self.eat_keyword("is") {
                        let negated = self.eat_keyword("not");
                        self.expect_keyword("null")?;
                        lhs = Expr::IsNull { expr: Box::new(lhs), negated };
                    } else if self.eat_keyword("in") {
                        lhs = self.in_list(lhs, false)?;
                    } else if self.eat_keyword("between") {
                        lhs = self.between(lhs, false)?;
                    } else if self.eat_keyword("not") {
                        if self.eat_keyword("in") {
                            lhs = self.in_list(lhs, true)?;
                        } else if self.eat_keyword("between") {
                            lhs = self.between(lhs, true)?;
                        } else {
                            bail!("expected IN or BETWEEN after NOT");
                        }
                    }
                }
            }
        }
        Ok(lhs)
    }

    fn in_list(&mut self, lhs: Expr, negated: bool) -> Result<Expr> {
        self.expect(&Token::LParen)?;
        let mut list = Vec::new();
        loop {
            list.push(self.expr(0)?);
            if !self.eat_comma() {
                break;
            }
        }
        self.expect(&Token::RParen)?;
        Ok(Expr::InList { expr: Box::new(lhs), list, negated })
    }

    fn between(&mut self, lhs: Expr, negated: bool) -> Result<Expr> {
        // Parse bounds above AND's precedence so the AND binds to BETWEEN.
        let low = self.expr(3)?;
        self.expect_keyword("and")?;
        let high = self.expr(3)?;
        Ok(Expr::Between {
            expr: Box::new(lhs),
            low: Box::new(low),
            high: Box::new(high),
            negated,
        })
    }

    fn prefix(&mut self) -> Result<Expr> {
        match self.next() {
            Some(Token::Int(v)) => Ok(Expr::Literal(Value::Int(v))),
            Some(Token::Float(v)) => Ok(Expr::Literal(Value::Float(v))),
            Some(Token::Str(s)) => Ok(Expr::Literal(Value::Str(s))),
            Some(Token::Minus) => {
                let e = self.expr(8)?;
                Ok(Expr::Unary { op: UnaryOp::Neg, expr: Box::new(e) })
            }
            Some(Token::LParen) => {
                let e = self.expr(0)?;
                self.expect(&Token::RParen)?;
                Ok(e)
            }
            Some(Token::Star) => Ok(Expr::Star),
            Some(Token::Ident(w)) => match w.as_str() {
                "null" => Ok(Expr::Literal(Value::Null)),
                "true" => Ok(Expr::Literal(Value::Bool(true))),
                "false" => Ok(Expr::Literal(Value::Bool(false))),
                "not" => {
                    let e = self.expr(3)?;
                    Ok(Expr::Unary { op: UnaryOp::Not, expr: Box::new(e) })
                }
                "case" => self.case_expr(),
                _ => self.ident_or_call(w),
            },
            Some(Token::QuotedIdent(s)) => Ok(Expr::Column(s)),
            other => bail!("unexpected token in expression: {other:?}"),
        }
    }

    fn case_expr(&mut self) -> Result<Expr> {
        let mut branches = Vec::new();
        let mut else_value = None;
        loop {
            if self.eat_keyword("when") {
                let cond = self.expr(0)?;
                self.expect_keyword("then")?;
                let value = self.expr(0)?;
                branches.push((cond, value));
            } else if self.eat_keyword("else") {
                else_value = Some(Box::new(self.expr(0)?));
            } else if self.eat_keyword("end") {
                break;
            } else {
                bail!("expected WHEN/ELSE/END in CASE, found {:?}", self.peek());
            }
        }
        if branches.is_empty() {
            bail!("CASE requires at least one WHEN branch");
        }
        Ok(Expr::Case { branches, else_value })
    }

    fn ident_or_call(&mut self, name: String) -> Result<Expr> {
        if self.peek() == Some(&Token::LParen) {
            self.pos += 1;
            let mut args = Vec::new();
            if self.peek() != Some(&Token::RParen) {
                loop {
                    if self.peek() == Some(&Token::Star) {
                        self.pos += 1;
                        args.push(Expr::Star);
                    } else {
                        args.push(self.expr(0)?);
                    }
                    if !self.eat_comma() {
                        break;
                    }
                }
            }
            self.expect(&Token::RParen)?;
            return Ok(Expr::Func { name, args });
        }
        // Qualified column `t.c` — the planner resolves on the last part;
        // we keep the qualifier for disambiguation.
        if self.peek() == Some(&Token::Dot) {
            self.pos += 1;
            let col = self.ident()?;
            return Ok(Expr::Column(format!("{name}.{col}")));
        }
        Ok(Expr::Column(name))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(sql: &str) -> Query {
        parse_query(sql).unwrap_or_else(|e| panic!("parse {sql:?}: {e}"))
    }

    #[test]
    fn simple_select() {
        let q = parse("SELECT a, b + 1 AS b1 FROM t WHERE a > 2 LIMIT 10");
        assert_eq!(q.select.len(), 2);
        assert!(matches!(&q.from, Some(TableRef::Table { name, .. }) if name == "t"));
        assert!(q.where_clause.is_some());
        assert_eq!(q.limit, Some(10));
    }

    #[test]
    fn precedence() {
        let q = parse("SELECT a + b * c FROM t");
        if let SelectItem::Expr { expr, .. } = &q.select[0] {
            assert_eq!(expr.to_sql(), "(a + (b * c))");
        } else {
            panic!()
        }
        let q = parse("SELECT a = 1 OR b = 2 AND c = 3 FROM t");
        if let SelectItem::Expr { expr, .. } = &q.select[0] {
            assert_eq!(expr.to_sql(), "((a = 1) OR ((b = 2) AND (c = 3)))");
        } else {
            panic!()
        }
    }

    #[test]
    fn group_by_having_order() {
        let q = parse(
            "SELECT cat, SUM(x) AS total FROM t GROUP BY cat HAVING SUM(x) > 5 \
             ORDER BY total DESC, cat LIMIT 3",
        );
        assert_eq!(q.group_by.len(), 1);
        assert!(q.having.is_some());
        assert_eq!(q.order_by.len(), 2);
        assert!(q.order_by[0].descending);
        assert!(!q.order_by[1].descending);
    }

    #[test]
    fn joins() {
        let q = parse("SELECT * FROM a JOIN b ON a.id = b.id LEFT JOIN c ON b.k = c.k");
        assert_eq!(q.joins.len(), 2);
        assert_eq!(q.joins[0].0, JoinKind::Inner);
        assert_eq!(q.joins[1].0, JoinKind::Left);
    }

    #[test]
    fn subquery_and_table_func() {
        let q = parse("SELECT x FROM (SELECT a AS x FROM t) sub WHERE x > 0");
        assert!(matches!(&q.from, Some(TableRef::Subquery { alias: Some(a), .. }) if a == "sub"));
        let q = parse("SELECT * FROM TABLE(explode_sessions(10, 'web')) s");
        match &q.from {
            Some(TableRef::TableFunc { name, args, alias }) => {
                assert_eq!(name, "explode_sessions");
                assert_eq!(args.len(), 2);
                assert_eq!(alias.as_deref(), Some("s"));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn count_star_and_functions() {
        let q = parse("SELECT COUNT(*), AVG(price), my_udf(a, 2) FROM t");
        if let SelectItem::Expr { expr, .. } = &q.select[0] {
            assert_eq!(expr, &Expr::Func { name: "count".into(), args: vec![Expr::Star] });
        } else {
            panic!()
        }
    }

    #[test]
    fn null_predicates_and_in_between() {
        let q = parse(
            "SELECT * FROM t WHERE a IS NOT NULL AND b IN (1, 2, 3) \
             AND c NOT BETWEEN 0 AND 9 AND d IS NULL",
        );
        let w = q.where_clause.unwrap().to_sql();
        assert!(w.contains("IS NOT NULL"), "{w}");
        assert!(w.contains("IN (1, 2, 3)"), "{w}");
        assert!(w.contains("NOT BETWEEN 0 AND 9"), "{w}");
    }

    #[test]
    fn case_expression() {
        let q = parse("SELECT CASE WHEN a > 0 THEN 'pos' ELSE 'neg' END AS sign FROM t");
        if let SelectItem::Expr { expr, alias } = &q.select[0] {
            assert!(matches!(expr, Expr::Case { .. }));
            assert_eq!(alias.as_deref(), Some("sign"));
        } else {
            panic!()
        }
    }

    #[test]
    fn unary_and_not() {
        let q = parse("SELECT -a, NOT b FROM t WHERE NOT a > 1");
        assert_eq!(q.select.len(), 2);
    }

    #[test]
    fn round_trip_to_sql() {
        for sql in [
            "SELECT a, (b + 1) AS b1 FROM t WHERE (a > 2) LIMIT 10",
            "SELECT cat, sum(x) AS total FROM sales GROUP BY cat ORDER BY total DESC",
            "SELECT * FROM a JOIN b ON (a.id = b.id) WHERE (x IS NULL)",
        ] {
            let q1 = parse(sql);
            let q2 = parse(&q1.to_sql());
            assert_eq!(q1, q2, "round-trip of {sql:?} via {:?}", q1.to_sql());
        }
    }

    #[test]
    fn errors() {
        assert!(parse_query("SELECT").is_err());
        assert!(parse_query("SELECT a FROM").is_err());
        assert!(parse_query("SELECT a FROM t WHERE").is_err());
        assert!(parse_query("SELECT a FROM t LIMIT -1").is_err());
        assert!(parse_query("SELECT a FROM t extra garbage !!").is_err());
        assert!(parse_query("SELECT CASE END FROM t").is_err());
    }

    #[test]
    fn qualified_columns() {
        let q = parse("SELECT t.a FROM t");
        if let SelectItem::Expr { expr, .. } = &q.select[0] {
            assert_eq!(expr, &Expr::Column("t.a".into()));
        } else {
            panic!()
        }
    }
}
