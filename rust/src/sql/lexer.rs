//! SQL tokenizer.

use anyhow::{bail, Result};

/// A lexed SQL token. Identifiers are folded to lowercase; keywords are
/// recognized at parse time (keeps the lexer tiny and the keyword set
/// extensible).
#[derive(Debug, Clone, PartialEq)]
pub enum Token {
    Ident(String),
    /// `"Quoted Identifier"` — preserved case.
    QuotedIdent(String),
    Int(i64),
    Float(f64),
    Str(String),
    /// `(`, `)`, `,`, `.`, `*`
    LParen,
    RParen,
    Comma,
    Dot,
    Star,
    /// Operators.
    Plus,
    Minus,
    Slash,
    Percent,
    Eq,
    NotEq,
    Lt,
    LtEq,
    Gt,
    GtEq,
    /// `||` string concat.
    Concat,
}

/// Tokenize a SQL string. `--` line comments are skipped.
pub fn tokenize(sql: &str) -> Result<Vec<Token>> {
    let bytes = sql.as_bytes();
    let mut out = Vec::new();
    let mut i = 0;
    while i < bytes.len() {
        let c = bytes[i] as char;
        match c {
            ' ' | '\t' | '\n' | '\r' => i += 1,
            '-' if bytes.get(i + 1) == Some(&b'-') => {
                while i < bytes.len() && bytes[i] != b'\n' {
                    i += 1;
                }
            }
            '(' => {
                out.push(Token::LParen);
                i += 1;
            }
            ')' => {
                out.push(Token::RParen);
                i += 1;
            }
            ',' => {
                out.push(Token::Comma);
                i += 1;
            }
            '.' if !bytes
                .get(i + 1)
                .map_or(false, |b| b.is_ascii_digit()) =>
            {
                out.push(Token::Dot);
                i += 1;
            }
            '*' => {
                out.push(Token::Star);
                i += 1;
            }
            '+' => {
                out.push(Token::Plus);
                i += 1;
            }
            '-' => {
                out.push(Token::Minus);
                i += 1;
            }
            '/' => {
                out.push(Token::Slash);
                i += 1;
            }
            '%' => {
                out.push(Token::Percent);
                i += 1;
            }
            '=' => {
                out.push(Token::Eq);
                i += 1;
            }
            '!' if bytes.get(i + 1) == Some(&b'=') => {
                out.push(Token::NotEq);
                i += 2;
            }
            '<' => {
                if bytes.get(i + 1) == Some(&b'=') {
                    out.push(Token::LtEq);
                    i += 2;
                } else if bytes.get(i + 1) == Some(&b'>') {
                    out.push(Token::NotEq);
                    i += 2;
                } else {
                    out.push(Token::Lt);
                    i += 1;
                }
            }
            '>' => {
                if bytes.get(i + 1) == Some(&b'=') {
                    out.push(Token::GtEq);
                    i += 2;
                } else {
                    out.push(Token::Gt);
                    i += 1;
                }
            }
            '|' if bytes.get(i + 1) == Some(&b'|') => {
                out.push(Token::Concat);
                i += 2;
            }
            '\'' => {
                // String literal; '' escapes a quote.
                let mut s = String::new();
                i += 1;
                loop {
                    match bytes.get(i) {
                        None => bail!("unterminated string literal"),
                        Some(b'\'') if bytes.get(i + 1) == Some(&b'\'') => {
                            s.push('\'');
                            i += 2;
                        }
                        Some(b'\'') => {
                            i += 1;
                            break;
                        }
                        Some(&b) => {
                            s.push(b as char);
                            i += 1;
                        }
                    }
                }
                out.push(Token::Str(s));
            }
            '"' => {
                let mut s = String::new();
                i += 1;
                loop {
                    match bytes.get(i) {
                        None => bail!("unterminated quoted identifier"),
                        Some(b'"') => {
                            i += 1;
                            break;
                        }
                        Some(&b) => {
                            s.push(b as char);
                            i += 1;
                        }
                    }
                }
                out.push(Token::QuotedIdent(s));
            }
            c if c.is_ascii_digit() || (c == '.' && bytes.get(i + 1).map_or(false, |b| b.is_ascii_digit())) => {
                let start = i;
                let mut is_float = false;
                while i < bytes.len()
                    && (bytes[i].is_ascii_digit()
                        || bytes[i] == b'.'
                        || bytes[i] == b'e'
                        || bytes[i] == b'E'
                        || ((bytes[i] == b'+' || bytes[i] == b'-')
                            && matches!(bytes.get(i - 1), Some(b'e') | Some(b'E'))))
                {
                    if bytes[i] == b'.' || bytes[i] == b'e' || bytes[i] == b'E' {
                        is_float = true;
                    }
                    i += 1;
                }
                let text = &sql[start..i];
                if is_float {
                    out.push(Token::Float(text.parse()?));
                } else {
                    match text.parse::<i64>() {
                        Ok(v) => out.push(Token::Int(v)),
                        Err(_) => out.push(Token::Float(text.parse()?)),
                    }
                }
            }
            c if c.is_ascii_alphabetic() || c == '_' => {
                let start = i;
                while i < bytes.len()
                    && ((bytes[i] as char).is_ascii_alphanumeric() || bytes[i] == b'_')
                {
                    i += 1;
                }
                out.push(Token::Ident(sql[start..i].to_ascii_lowercase()));
            }
            other => bail!("unexpected character {other:?} at byte {i}"),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_select() {
        let toks = tokenize("SELECT a, b FROM t WHERE a >= 1.5").unwrap();
        assert_eq!(
            toks,
            vec![
                Token::Ident("select".into()),
                Token::Ident("a".into()),
                Token::Comma,
                Token::Ident("b".into()),
                Token::Ident("from".into()),
                Token::Ident("t".into()),
                Token::Ident("where".into()),
                Token::Ident("a".into()),
                Token::GtEq,
                Token::Float(1.5),
            ]
        );
    }

    #[test]
    fn strings_and_escapes() {
        let toks = tokenize("'it''s'").unwrap();
        assert_eq!(toks, vec![Token::Str("it's".into())]);
        assert!(tokenize("'open").is_err());
    }

    #[test]
    fn operators() {
        let toks = tokenize("a <> b != c || d < e <= f").unwrap();
        assert_eq!(
            toks,
            vec![
                Token::Ident("a".into()),
                Token::NotEq,
                Token::Ident("b".into()),
                Token::NotEq,
                Token::Ident("c".into()),
                Token::Concat,
                Token::Ident("d".into()),
                Token::Lt,
                Token::Ident("e".into()),
                Token::LtEq,
                Token::Ident("f".into()),
            ]
        );
    }

    #[test]
    fn numbers() {
        let toks = tokenize("1 2.5 1e3 .5 123456789012345678901234567890").unwrap();
        assert_eq!(toks[0], Token::Int(1));
        assert_eq!(toks[1], Token::Float(2.5));
        assert_eq!(toks[2], Token::Float(1000.0));
        assert_eq!(toks[3], Token::Float(0.5));
        assert!(matches!(toks[4], Token::Float(_))); // overflow falls back
    }

    #[test]
    fn comments_skipped() {
        let toks = tokenize("select 1 -- trailing\n, 2").unwrap();
        assert_eq!(toks.len(), 4);
    }

    #[test]
    fn identifiers_fold_case_quoted_preserve() {
        let toks = tokenize("MyCol \"MyCol\"").unwrap();
        assert_eq!(toks[0], Token::Ident("mycol".into()));
        assert_eq!(toks[1], Token::QuotedIdent("MyCol".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(tokenize("select @").is_err());
    }
}
