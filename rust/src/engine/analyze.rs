//! Plan-time semantic analysis: resolve, type-check, estimate — never
//! execute a row.
//!
//! The paper's Snowpark client validates lazily-built DataFrame plans
//! *before* the server runs them (§III): unknown columns, type
//! mismatches, and malformed calls surface at `collect()`-build time,
//! not halfway through a warehouse scan. This module gives the engine
//! the same front door. [`analyze_plan`] walks a [`Plan`] bottom-up,
//! mirroring the executor's resolution and kernel-typing rules
//! *exactly* (same `resolve_column` candidate logic, same
//! `Value`-coercion table the kernels use), and produces an
//! [`Analysis`]: the statement's inferred output schema, cardinality
//! and byte estimates for the admission estimator's cold path, a
//! fragment-eligibility report, and structured [`Diagnostic`]s carrying
//! a stable [`DiagCode`] plus the operator path
//! (`Scan(store_sales) → Filter → Aggregate`) where the problem lives.
//!
//! The contract, pinned by `tests/analyze_differential.rs`:
//!
//! - **accept ⇒ runnable**: a statement with no error-severity
//!   diagnostics can never fail execution with a resolution or type
//!   error;
//! - **reject ⇒ broken**: a statement rejected with an `E1xx` type code
//!   fails execution with the *same* code (the kernels raise their
//!   errors through the shared constructors below), and a statement
//!   rejected with `E130` (non-boolean predicate) silently misresolves
//!   at runtime — the kernel masks a non-boolean predicate to all-false
//!   and returns zero rows.
//!
//! The analyzer is deliberately conservative: any type it cannot pin
//! statically (NULL literals, UDF outputs it has no metadata for,
//! columns of unknown tables) becomes [`Ty::Unknown`], which never
//! participates in a rejection. Only a provable runtime failure is an
//! error; everything data-dependent (mixed CASE branches, IN-list items
//! that can never match) is a `W`-coded lint.

use std::fmt;

use anyhow::Error;

use crate::sql::{parse_query, BinaryOp, Expr, UnaryOp};
use crate::types::{DataType, Value};
use crate::udf::UdfRegistry;

use super::catalog::Catalog;
use super::fragment::{fuse_report, FuseNote};
use super::plan::{plan_query, AggCall, AggFunc, Plan};

// ------------------------------------------------------------------ codes

/// Stable diagnostic codes. `E…` codes are errors (the analyzer rejects
/// the statement); `W…` codes are lints (the statement runs, but
/// probably not the way the author meant).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[allow(missing_docs)] // each code is documented by `describe()`
pub enum DiagCode {
    E000,
    E001,
    E002,
    E003,
    E004,
    E010,
    E101,
    E102,
    E103,
    E104,
    E105,
    E106,
    E110,
    E111,
    E113,
    E120,
    E121,
    E130,
    W001,
    W002,
    W003,
    W004,
    W005,
    W006,
    W007,
    W008,
}

impl DiagCode {
    /// The stable code string (`"E001"`, `"W003"`, …).
    pub fn as_str(&self) -> &'static str {
        match self {
            DiagCode::E000 => "E000",
            DiagCode::E001 => "E001",
            DiagCode::E002 => "E002",
            DiagCode::E003 => "E003",
            DiagCode::E004 => "E004",
            DiagCode::E010 => "E010",
            DiagCode::E101 => "E101",
            DiagCode::E102 => "E102",
            DiagCode::E103 => "E103",
            DiagCode::E104 => "E104",
            DiagCode::E105 => "E105",
            DiagCode::E106 => "E106",
            DiagCode::E110 => "E110",
            DiagCode::E111 => "E111",
            DiagCode::E113 => "E113",
            DiagCode::E120 => "E120",
            DiagCode::E121 => "E121",
            DiagCode::E130 => "E130",
            DiagCode::W001 => "W001",
            DiagCode::W002 => "W002",
            DiagCode::W003 => "W003",
            DiagCode::W004 => "W004",
            DiagCode::W005 => "W005",
            DiagCode::W006 => "W006",
            DiagCode::W007 => "W007",
            DiagCode::W008 => "W008",
        }
    }

    /// One-line description of what the code means (the ARCHITECTURE
    /// diagnostic table is generated from the same wording).
    pub fn describe(&self) -> &'static str {
        match self {
            DiagCode::E000 => "syntax error",
            DiagCode::E001 => "unknown column",
            DiagCode::E002 => "ambiguous column reference",
            DiagCode::E003 => "unknown table or table function",
            DiagCode::E004 => "unknown function",
            DiagCode::E010 => "statement cannot be planned",
            DiagCode::E101 => "arithmetic on a non-numeric operand",
            DiagCode::E102 => "incomparable comparison operands",
            DiagCode::E103 => "AND/OR over a non-boolean operand",
            DiagCode::E104 => "NOT over a non-boolean operand",
            DiagCode::E105 => "negation of a non-numeric operand",
            DiagCode::E106 => "BETWEEN operand type mismatch",
            DiagCode::E110 => "wrong number of arguments to a builtin",
            DiagCode::E111 => "wrong argument type for a builtin",
            DiagCode::E113 => "aggregate call in a scalar-only position",
            DiagCode::E120 => "SUM/AVG over a non-numeric argument",
            DiagCode::E121 => "aggregate call missing its argument",
            DiagCode::E130 => "non-boolean predicate (would drop every row)",
            DiagCode::W001 => "predicate is constant true",
            DiagCode::W002 => "predicate is constant false/NULL — drops every row",
            DiagCode::W003 => "comparison with a NULL literal is never true",
            DiagCode::W004 => "projected column is never referenced",
            DiagCode::W005 => "IN list item of mismatched type can never match",
            DiagCode::W006 => "non-boolean CASE condition never matches",
            DiagCode::W007 => "join key types are incomparable — keys never match",
            DiagCode::W008 => "CASE/COALESCE branches mix incompatible types",
        }
    }

    /// Is this a rejecting (error) code, as opposed to a lint?
    pub fn is_error(&self) -> bool {
        self.as_str().starts_with('E')
    }
}

impl fmt::Display for DiagCode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Diagnostic severity, derived from the code class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    /// The statement is rejected.
    Error,
    /// The statement runs, but the plan looks wrong.
    Warning,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Severity::Error => "error",
            Severity::Warning => "warning",
        })
    }
}

/// One analyzer finding: a coded message anchored to the operator path
/// where it was detected.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    /// Stable code (`E001`, `W003`, …).
    pub code: DiagCode,
    /// Error (rejecting) or warning (lint).
    pub severity: Severity,
    /// Human-readable message.
    pub message: String,
    /// Operator path, e.g. `Scan(store_sales) → Filter → Aggregate`.
    pub path: String,
}

impl Diagnostic {
    fn new(code: DiagCode, path: &str, message: String) -> Self {
        let severity = if code.is_error() {
            Severity::Error
        } else {
            Severity::Warning
        };
        Diagnostic { code, severity, message, path: path.to_string() }
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}[{}] at {}: {}",
            self.severity, self.code, self.path, self.message
        )
    }
}

// --------------------------------------------- shared error constructors
//
// The kernels (columnar *and* row-wise, which used to duplicate these
// strings independently) raise their type errors through these
// constructors, so a runtime failure carries the same code the analyzer
// predicts — differential tests compare error identity, not prose.

/// `E101`: arithmetic kernel met a non-numeric operand.
pub(crate) fn err_arith(v: impl fmt::Display) -> Error {
    anyhow::anyhow!("E101: arith on {v}")
}

/// `E102`: comparison kernel met incomparable operands.
pub(crate) fn err_compare(l: impl fmt::Display, r: impl fmt::Display) -> Error {
    anyhow::anyhow!("E102: cannot compare {l} with {r}")
}

/// `E103`: logic kernel met a non-boolean operand.
pub(crate) fn err_logic() -> Error {
    anyhow::anyhow!("E103: AND/OR expects booleans")
}

/// `E104`: NOT over a non-boolean.
pub(crate) fn err_not(v: impl fmt::Display) -> Error {
    anyhow::anyhow!("E104: NOT expects a boolean, got {v}")
}

/// `E105`: negation of a non-numeric.
pub(crate) fn err_negate(v: impl fmt::Display) -> Error {
    anyhow::anyhow!("E105: cannot negate {v}")
}

/// `E106`: BETWEEN operand types are incomparable.
pub(crate) fn err_between() -> Error {
    anyhow::anyhow!("E106: BETWEEN type mismatch")
}

/// `E110`: builtin called with the wrong number of arguments
/// (`detail` is the builtin's own arity phrasing).
pub(crate) fn err_builtin_arity(detail: impl fmt::Display) -> Error {
    anyhow::anyhow!("E110: {detail}")
}

/// `E111`: builtin called with a wrongly-typed argument.
pub(crate) fn err_builtin_arg(detail: impl fmt::Display) -> Error {
    anyhow::anyhow!("E111: {detail}")
}

/// `E120`: SUM/AVG folded a non-numeric value.
pub(crate) fn err_agg_non_numeric(what: impl fmt::Display, v: impl fmt::Display) -> Error {
    anyhow::anyhow!("E120: {what} over non-numeric {v}")
}

/// `E001`: column not found.
pub(crate) fn err_unknown_column(name: &str, available: Vec<&str>) -> Error {
    anyhow::anyhow!("E001: column {name:?} not found (available: {available:?})")
}

/// `E002`: column reference matches several fields.
pub(crate) fn err_ambiguous_column(name: &str) -> Error {
    anyhow::anyhow!("E002: column {name:?} is ambiguous")
}

/// `E004`: no builtin or registered function under this name.
pub(crate) fn err_unknown_function(name: &str) -> Error {
    anyhow::anyhow!("E004: unknown function {name:?}")
}

// ---------------------------------------------------------------- types

/// Analyzer-side type lattice: either a concrete engine [`DataType`] or
/// `Unknown` (NULL literals, unresolvable columns, UDFs without
/// metadata). `Unknown` never participates in a rejection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ty {
    /// A concrete, statically-known column type.
    Known(DataType),
    /// Statically undetermined; compatible with everything.
    Unknown,
}

impl Ty {
    fn known(self) -> Option<DataType> {
        match self {
            Ty::Known(dt) => Some(dt),
            Ty::Unknown => None,
        }
    }

    /// Definitely numeric / definitely not numeric / unknown.
    fn non_numeric(self) -> bool {
        matches!(self, Ty::Known(DataType::Utf8) | Ty::Known(DataType::Bool))
    }

    fn is_known(self, dt: DataType) -> bool {
        self == Ty::Known(dt)
    }

    /// Estimated bytes per row for a column of this type (mirrors
    /// `Column::byte_size`: fixed 8-byte numerics, 1-byte bools, and a
    /// 40-byte average for strings).
    fn width(&self) -> u64 {
        match self {
            Ty::Known(DataType::Bool) => 1,
            Ty::Known(DataType::Utf8) => 40,
            _ => 8,
        }
    }
}

impl fmt::Display for Ty {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Ty::Known(dt) => write!(f, "{dt}"),
            Ty::Unknown => f.write_str("?"),
        }
    }
}

/// Can the comparison kernel order these two types? Mirrors `cell_cmp`:
/// numeric×numeric, string×string, bool×bool.
fn comparable(a: DataType, b: DataType) -> bool {
    let num = |d: DataType| matches!(d, DataType::Int64 | DataType::Float64);
    (num(a) && num(b)) || a == b
}

// ------------------------------------------------------------- analysis

/// The result of analyzing one statement: diagnostics, the inferred
/// output schema, cardinality/byte estimates, and the
/// fragment-eligibility report.
#[derive(Debug, Default)]
pub struct Analysis {
    /// All findings, in discovery order (bottom-up over the plan).
    pub diagnostics: Vec<Diagnostic>,
    /// Inferred output schema: `(column name, type)` in output order.
    pub schema: Vec<(String, Ty)>,
    /// Estimated output rows.
    pub est_rows: u64,
    /// Estimated total rows read by every scan in the plan.
    pub est_scan_rows: u64,
    /// Estimated output bytes (`schema width × est_rows`).
    pub est_output_bytes: u64,
    /// Fragment-eligibility report: one note per fusion candidate (over
    /// the *optimized* physical plan — what the executor actually runs).
    pub fragments: Vec<FuseNote>,
    /// The optimized physical plan: the rendered tree (with per-node
    /// cardinality/byte estimates) plus the rewrite rules that fired, in
    /// the stable text format of [`super::rewrite::explain_plan`].
    /// Empty when the statement failed to parse or plan.
    pub optimized: String,
}

impl Analysis {
    /// No error-severity diagnostics — the statement may execute.
    pub fn is_ok(&self) -> bool {
        self.diagnostics
            .iter()
            .all(|d| d.severity != Severity::Error)
    }

    /// Only the rejecting diagnostics.
    pub fn errors(&self) -> impl Iterator<Item = &Diagnostic> {
        self.diagnostics
            .iter()
            .filter(|d| d.severity == Severity::Error)
    }

    /// Memory-footprint hint for the admission estimator's cold path:
    /// predicted result bytes plus the per-scanned-row surcharge the
    /// server's actual-usage recorder applies (`SCAN_BYTES_PER_ROW`).
    pub fn cold_bytes_hint(&self) -> u64 {
        (self.est_output_bytes + 64 * self.est_scan_rows).max(1)
    }

    /// Render every error diagnostic as one line each (the message a
    /// rejected statement surfaces to the session / wire client).
    pub fn render_errors(&self) -> String {
        self.errors()
            .map(|d| d.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    }

    /// Full human-readable report: diagnostics, schema, estimates, and
    /// the fragment-eligibility notes (what `run-sql --explain` prints).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for d in &self.diagnostics {
            out.push_str(&d.to_string());
            out.push('\n');
        }
        out.push_str("schema:");
        if self.schema.is_empty() {
            out.push_str(" (none)");
        }
        out.push('\n');
        for (name, ty) in &self.schema {
            out.push_str(&format!("  {name}: {ty}\n"));
        }
        out.push_str(&format!(
            "estimate: ~{} rows out, ~{} rows scanned, ~{} bytes (admission hint {})\n",
            self.est_rows,
            self.est_scan_rows,
            self.est_output_bytes,
            self.cold_bytes_hint()
        ));
        if self.fragments.is_empty() {
            out.push_str("fragments: no fusion candidates\n");
        } else {
            out.push_str("fragments:\n");
            for n in &self.fragments {
                if n.fused {
                    out.push_str(&format!("  fused [{}]\n", n.ops.join("+")));
                } else {
                    out.push_str(&format!(
                        "  declined [{}]: {}\n",
                        n.ops.join("+"),
                        n.reason
                    ));
                }
            }
        }
        if !self.optimized.is_empty() {
            out.push_str("optimized plan:\n");
            for line in self.optimized.lines() {
                out.push_str("  ");
                out.push_str(line);
                out.push('\n');
            }
        }
        out
    }
}

/// Is the pre-execution analyzer gate enabled? On by default; set
/// `SNOWPARK_ANALYZE=0` to run statements unchecked (escape hatch for
/// comparing against raw-engine behavior). Deprecation shim over
/// [`super::config::EngineConfig::from_env`].
pub fn analysis_enabled() -> bool {
    super::config::EngineConfig::from_env().analyze
}

/// Parse, plan, and analyze one SQL statement. Parse failures become a
/// single `E000` diagnostic; planner rejections become `E010`.
pub fn analyze_sql(sql: &str, catalog: &Catalog, udfs: &UdfRegistry) -> Analysis {
    let q = match parse_query(sql) {
        Ok(q) => q,
        Err(e) => {
            let mut a = Analysis::default();
            a.diagnostics
                .push(Diagnostic::new(DiagCode::E000, "(parse)", format!("{e:#}")));
            return a;
        }
    };
    let plan = match plan_query(&q, udfs) {
        Ok(p) => p,
        Err(e) => {
            let mut a = Analysis::default();
            a.diagnostics
                .push(Diagnostic::new(DiagCode::E010, "(plan)", format!("{e:#}")));
            return a;
        }
    };
    analyze_plan(&plan, catalog, udfs)
}

/// Analyze an already-planned statement.
pub fn analyze_plan(plan: &Plan, catalog: &Catalog, udfs: &UdfRegistry) -> Analysis {
    let mut az = Analyzer {
        catalog,
        udfs,
        diags: Vec::new(),
        scan_rows: 0,
    };
    let root = az.walk(plan, None);
    let est_output_bytes = root
        .cols
        .iter()
        .map(|(_, t)| t.width())
        .sum::<u64>()
        .saturating_mul(root.est_rows);
    // The eligibility report and the explain tree both describe the
    // *optimized* physical plan — exactly what the executor runs.
    let (phys, _) = super::rewrite::rewrite_plan(plan, Some(catalog), udfs);
    Analysis {
        diagnostics: az.diags,
        schema: root.cols,
        est_rows: root.est_rows,
        est_scan_rows: az.scan_rows,
        est_output_bytes,
        fragments: fuse_report(&phys, udfs),
        optimized: super::rewrite::explain_plan(plan, Some(catalog), udfs),
    }
}

// ------------------------------------------------------------- the walk

/// What the walk knows about one operator's output.
struct NodeInfo {
    /// Output columns, in order, with their analyzer types.
    cols: Vec<(String, Ty)>,
    /// Estimated output rows.
    est_rows: u64,
    /// Operator path from the deepest source to this node.
    path: String,
    /// The source schema is unknown (unknown table): suppress
    /// resolution errors above, they would only cascade.
    poisoned: bool,
}

/// Outcome of mirroring `resolve_column` against an analyzer scope.
enum Resolution {
    Hit(usize),
    NotFound,
    Ambiguous,
}

/// Exact mirror of `expr::resolve_column` over `(name, ty)` scopes:
/// case-insensitive whole-name match first, then the qualified/bare
/// suffix candidate rules.
fn resolve(cols: &[(String, Ty)], name: &str) -> Resolution {
    if let Some(i) = cols
        .iter()
        .position(|(n, _)| n.eq_ignore_ascii_case(name))
    {
        return Resolution::Hit(i);
    }
    let candidates: Vec<usize> = if let Some((_, bare)) = name.split_once('.') {
        cols.iter()
            .enumerate()
            .filter(|(_, (n, _))| n.eq_ignore_ascii_case(bare))
            .map(|(i, _)| i)
            .collect()
    } else {
        cols.iter()
            .enumerate()
            .filter(|(_, (n, _))| {
                n.rsplit_once('.')
                    .map_or(false, |(_, suffix)| suffix.eq_ignore_ascii_case(name))
            })
            .map(|(i, _)| i)
            .collect()
    };
    match candidates.len() {
        0 => Resolution::NotFound,
        1 => Resolution::Hit(candidates[0]),
        _ => Resolution::Ambiguous,
    }
}

struct Analyzer<'a> {
    catalog: &'a Catalog,
    udfs: &'a UdfRegistry,
    diags: Vec<Diagnostic>,
    scan_rows: u64,
}

impl<'a> Analyzer<'a> {
    fn diag(&mut self, code: DiagCode, path: &str, message: String) {
        self.diags.push(Diagnostic::new(code, path, message));
    }

    /// Bottom-up walk. `needed` is the set of output names the parent
    /// will reference (`None` = everything may be referenced), used only
    /// for the W004 unused-projection lint.
    fn walk(&mut self, plan: &Plan, needed: Option<&[String]>) -> NodeInfo {
        match plan {
            Plan::Scan { table, alias } => {
                let label = alias.as_deref().unwrap_or(table);
                let path = format!("Scan({label})");
                match self.catalog.schema_of(table) {
                    Some((schema, rows)) => {
                        self.scan_rows += rows as u64;
                        NodeInfo {
                            cols: schema
                                .fields
                                .iter()
                                .map(|f| (f.name.clone(), Ty::Known(f.data_type)))
                                .collect(),
                            est_rows: rows as u64,
                            path,
                            poisoned: false,
                        }
                    }
                    None => {
                        self.diag(
                            DiagCode::E003,
                            &path,
                            format!(
                                "table {table:?} not found (available: {:?})",
                                self.catalog.table_names()
                            ),
                        );
                        NodeInfo { cols: Vec::new(), est_rows: 0, path, poisoned: true }
                    }
                }
            }
            Plan::TableFunc { name, args, alias } => {
                // UDTF arguments are evaluated against the executor's
                // one-row dummy schema, so plain column references in
                // them cannot resolve.
                let dummy = vec![("__dummy".to_string(), Ty::Known(DataType::Int64))];
                let arg_scope = NodeInfo {
                    cols: dummy.clone(),
                    est_rows: 1,
                    path: format!("TableFunc({name})"),
                    poisoned: false,
                };
                for a in args {
                    self.type_expr(a, &arg_scope);
                }
                if name == "__dual" {
                    return NodeInfo {
                        cols: dummy,
                        est_rows: 1,
                        path: "Dual".to_string(),
                        poisoned: false,
                    };
                }
                let label = alias.as_deref().unwrap_or(name);
                let path = format!("TableFunc({label})");
                // The executor resolves a table-function name against the
                // catalog first, then the UDTF registry — mirror that.
                if let Some((schema, rows)) = self.catalog.schema_of(name) {
                    self.scan_rows += rows as u64;
                    return NodeInfo {
                        cols: schema
                            .fields
                            .iter()
                            .map(|f| (f.name.clone(), Ty::Known(f.data_type)))
                            .collect(),
                        est_rows: rows as u64,
                        path,
                        poisoned: false,
                    };
                }
                if let Some(udtf) = self.udfs.udtf(name) {
                    self.scan_rows += 64;
                    return NodeInfo {
                        cols: udtf
                            .schema
                            .fields
                            .iter()
                            .map(|f| (f.name.clone(), Ty::Known(f.data_type)))
                            .collect(),
                        est_rows: 64,
                        path,
                        poisoned: false,
                    };
                }
                self.diag(
                    DiagCode::E003,
                    &path,
                    format!("no table or table function named {name:?}"),
                );
                NodeInfo { cols: Vec::new(), est_rows: 0, path, poisoned: true }
            }
            Plan::Filter { input, predicate } => {
                let child_needed = extend_needed(needed, std::slice::from_ref(predicate));
                let mut node = self.walk(input, child_needed.as_deref());
                node.path.push_str(" → Filter");
                let ty = self.type_expr(predicate, &node);
                if ty.known().is_some() && !ty.is_known(DataType::Bool) {
                    // Known non-boolean predicate: the kernel masks it to
                    // all-false and silently returns zero rows.
                    self.diag(
                        DiagCode::E130,
                        &node.path,
                        format!("predicate has type {ty}, expected BOOLEAN — every row would be dropped"),
                    );
                }
                self.lint_predicate(predicate, &node);
                node.est_rows = match const_truth(predicate) {
                    Some(false) => 0,
                    Some(true) => node.est_rows,
                    None => (node.est_rows / 3).max(1).min(node.est_rows),
                };
                node
            }
            Plan::Project { input, exprs } => {
                let star = exprs.iter().any(|(e, _)| {
                    matches!(e, Expr::Star)
                        || matches!(e, Expr::Func { name, .. } if name == "__drop_hidden")
                });
                let child_needed = if star {
                    None
                } else {
                    extend_needed(Some(&[]), exprs.iter().map(|(e, _)| e))
                };
                let mut node = self.walk(input, child_needed.as_deref());
                node.path.push_str(" → Project");
                let mut cols: Vec<(String, Ty)> = Vec::new();
                for (e, out_name) in exprs {
                    match e {
                        Expr::Star => {
                            cols.extend(node.cols.iter().cloned());
                        }
                        Expr::Func { name, .. } if name == "__drop_hidden" => {
                            cols.extend(
                                node.cols
                                    .iter()
                                    .filter(|(n, _)| !n.starts_with("__sort_"))
                                    .cloned(),
                            );
                        }
                        _ => {
                            let ty = self.type_expr(e, &node);
                            cols.push((out_name.clone(), ty));
                        }
                    }
                }
                // W004: a projected name the parent provably never reads.
                if let Some(need) = needed {
                    for (_, out_name) in exprs {
                        if out_name == "*" || out_name.starts_with("__sort_") {
                            continue;
                        }
                        let used = need.iter().any(|n| name_matches(n, out_name));
                        if !used {
                            self.diag(
                                DiagCode::W004,
                                &node.path,
                                format!("column {out_name:?} is projected but never referenced"),
                            );
                        }
                    }
                }
                node.cols = cols;
                node
            }
            Plan::Aggregate { input, group, aggs } => {
                let needed_exprs: Vec<&Expr> = group
                    .iter()
                    .map(|(e, _)| e)
                    .chain(aggs.iter().flat_map(|a| a.args.iter()))
                    .collect();
                let child_needed =
                    extend_needed(Some(&[]), needed_exprs.iter().copied());
                let mut node = self.walk(input, child_needed.as_deref());
                node.path.push_str(" → Aggregate");
                let mut cols: Vec<(String, Ty)> = Vec::new();
                for (e, name) in group {
                    let ty = self.type_expr(e, &node);
                    cols.push((name.clone(), ty));
                }
                for call in aggs {
                    let ty = self.type_agg(call, &node);
                    cols.push((call.out_name.clone(), ty));
                }
                node.est_rows = if group.is_empty() {
                    1
                } else {
                    ((node.est_rows as f64).sqrt().ceil() as u64)
                        .clamp(1, node.est_rows.max(1))
                };
                node.cols = cols;
                node
            }
            Plan::Join { left, right, equi, residual, .. } => {
                let l = self.walk(left, None);
                let r = self.walk(right, None);
                let lalias = plan_label(left, "l");
                let ralias = plan_label(right, "r");
                // Mirror `exec::join_schema`: colliding names get
                // `{alias}.{name}` on both sides, the rest stay bare.
                let collides = |name: &str| {
                    l.cols.iter().any(|(n, _)| n.eq_ignore_ascii_case(name))
                        && r.cols.iter().any(|(n, _)| n.eq_ignore_ascii_case(name))
                };
                let mut cols: Vec<(String, Ty)> = Vec::new();
                for (n, t) in &l.cols {
                    let name = if collides(n) { format!("{lalias}.{n}") } else { n.clone() };
                    cols.push((name, *t));
                }
                for (n, t) in &r.cols {
                    let name = if collides(n) { format!("{ralias}.{n}") } else { n.clone() };
                    cols.push((name, *t));
                }
                let path = format!("{} → Join({})", l.path, ralias);
                let node = NodeInfo {
                    cols,
                    est_rows: if equi.is_empty() {
                        l.est_rows.saturating_mul(r.est_rows.max(1))
                    } else {
                        l.est_rows.max(r.est_rows)
                    },
                    path,
                    poisoned: l.poisoned || r.poisoned,
                };
                for (le, re) in equi {
                    // Equi keys are resolved side-by-side at execution
                    // time; accept a reference that resolves against the
                    // combined schema or either side alone.
                    let lt = self.type_equi_key(le, &node, &l, &r);
                    let rt = self.type_equi_key(re, &node, &l, &r);
                    if let (Some(a), Some(b)) = (lt.known(), rt.known()) {
                        if !comparable(a, b) {
                            self.diag(
                                DiagCode::W007,
                                &node.path,
                                format!(
                                    "equi-join key types {a} and {b} are incomparable — keys never match"
                                ),
                            );
                        }
                    }
                }
                if let Some(res) = residual {
                    let ty = self.type_expr(res, &node);
                    if ty.known().is_some() && !ty.is_known(DataType::Bool) {
                        self.diag(
                            DiagCode::E130,
                            &node.path,
                            format!("join residual predicate has type {ty}, expected BOOLEAN"),
                        );
                    }
                }
                node
            }
            Plan::Sort { input, keys } => {
                let key_exprs: Vec<&Expr> = keys.iter().map(|k| &k.expr).collect();
                let child_needed = extend_needed(needed, key_exprs.iter().copied());
                let mut node = self.walk(input, child_needed.as_deref());
                node.path.push_str(" → Sort");
                for k in keys {
                    self.type_expr(&k.expr, &node);
                }
                node
            }
            Plan::Limit { input, n } => {
                let mut node = self.walk(input, needed);
                node.path.push_str(" → Limit");
                node.est_rows = node.est_rows.min(*n as u64);
                node
            }
        }
    }

    /// Equi-join key: try the combined schema, then each side (the
    /// executor assigns sides schema-dependently at run time).
    fn type_equi_key(
        &mut self,
        e: &Expr,
        combined: &NodeInfo,
        l: &NodeInfo,
        r: &NodeInfo,
    ) -> Ty {
        if let Expr::Column(name) = e {
            for scope in [&combined.cols, &l.cols, &r.cols] {
                if let Resolution::Hit(i) = resolve(scope, name) {
                    return scope[i].1;
                }
            }
            if combined.poisoned {
                return Ty::Unknown;
            }
            // Distinguish ambiguous-everywhere from absent-everywhere.
            if matches!(resolve(&combined.cols, name), Resolution::Ambiguous) {
                self.diag(
                    DiagCode::E002,
                    &combined.path,
                    format!("column {name:?} is ambiguous"),
                );
            } else {
                self.diag(
                    DiagCode::E001,
                    &combined.path,
                    format!(
                        "column {name:?} not found (available: {:?})",
                        combined.cols.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>()
                    ),
                );
            }
            Ty::Unknown
        } else {
            self.type_expr(e, combined)
        }
    }

    /// Type one aggregate call against the aggregate's input scope.
    fn type_agg(&mut self, call: &AggCall, node: &NodeInfo) -> Ty {
        if call.func != AggFunc::CountStar && call.args.is_empty() {
            // The kernel indexes args[0] unconditionally — this would
            // not even be a clean runtime error.
            self.diag(
                DiagCode::E121,
                &node.path,
                format!("{}() needs an argument (or use count(*))", call.name),
            );
            return Ty::Unknown;
        }
        let arg_ty = call.args.first().map(|e| self.type_expr(e, node));
        for extra in call.args.iter().skip(1) {
            self.type_expr(extra, node);
        }
        match call.func {
            AggFunc::Count | AggFunc::CountStar => Ty::Known(DataType::Int64),
            AggFunc::Avg | AggFunc::Sum => {
                let ty = arg_ty.unwrap_or(Ty::Unknown);
                if ty.non_numeric() {
                    self.diag(
                        DiagCode::E120,
                        &node.path,
                        format!(
                            "{} over non-numeric argument of type {ty}",
                            call.name.to_uppercase()
                        ),
                    );
                    return Ty::Unknown;
                }
                if call.func == AggFunc::Avg {
                    Ty::Known(DataType::Float64)
                } else {
                    ty
                }
            }
            AggFunc::Min | AggFunc::Max => arg_ty.unwrap_or(Ty::Unknown),
            AggFunc::Udaf => self
                .udfs
                .udaf(&call.name)
                .map(|u| Ty::Known(u.return_type))
                .unwrap_or(Ty::Unknown),
        }
    }

    /// Infer the type of `e` against `node`'s scope, emitting diagnostics
    /// for every mismatch the kernels would raise at run time.
    fn type_expr(&mut self, e: &Expr, node: &NodeInfo) -> Ty {
        match e {
            Expr::Literal(v) => v.data_type().map(Ty::Known).unwrap_or(Ty::Unknown),
            Expr::Star => Ty::Unknown,
            Expr::Column(name) => {
                if node.poisoned {
                    return Ty::Unknown;
                }
                match resolve(&node.cols, name) {
                    Resolution::Hit(i) => node.cols[i].1,
                    Resolution::NotFound => {
                        self.diag(
                            DiagCode::E001,
                            &node.path,
                            format!(
                                "column {name:?} not found (available: {:?})",
                                node.cols.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>()
                            ),
                        );
                        Ty::Unknown
                    }
                    Resolution::Ambiguous => {
                        self.diag(
                            DiagCode::E002,
                            &node.path,
                            format!("column {name:?} is ambiguous"),
                        );
                        Ty::Unknown
                    }
                }
            }
            Expr::Unary { op, expr } => {
                let t = self.type_expr(expr, node);
                match op {
                    UnaryOp::Neg => {
                        if t.non_numeric() {
                            self.diag(
                                DiagCode::E105,
                                &node.path,
                                format!("cannot negate a value of type {t}"),
                            );
                            Ty::Unknown
                        } else {
                            t
                        }
                    }
                    UnaryOp::Not => {
                        if t.known().is_some() && !t.is_known(DataType::Bool) {
                            self.diag(
                                DiagCode::E104,
                                &node.path,
                                format!("NOT expects a BOOLEAN, got {t}"),
                            );
                        }
                        Ty::Known(DataType::Bool)
                    }
                }
            }
            Expr::Binary { op, left, right } => {
                let lt = self.type_expr(left, node);
                let rt = self.type_expr(right, node);
                match op {
                    BinaryOp::And | BinaryOp::Or => {
                        for t in [lt, rt] {
                            if t.known().is_some() && !t.is_known(DataType::Bool) {
                                self.diag(
                                    DiagCode::E103,
                                    &node.path,
                                    format!("AND/OR expects BOOLEAN operands, got {t}"),
                                );
                            }
                        }
                        Ty::Known(DataType::Bool)
                    }
                    BinaryOp::Eq
                    | BinaryOp::NotEq
                    | BinaryOp::Lt
                    | BinaryOp::LtEq
                    | BinaryOp::Gt
                    | BinaryOp::GtEq => {
                        if let (Some(a), Some(b)) = (lt.known(), rt.known()) {
                            if !comparable(a, b) {
                                self.diag(
                                    DiagCode::E102,
                                    &node.path,
                                    format!("cannot compare {a} with {b}"),
                                );
                            }
                        }
                        Ty::Known(DataType::Bool)
                    }
                    BinaryOp::Concat => Ty::Known(DataType::Utf8),
                    BinaryOp::Div => {
                        for t in [lt, rt] {
                            if t.non_numeric() {
                                self.diag(
                                    DiagCode::E101,
                                    &node.path,
                                    format!("arithmetic on a value of type {t}"),
                                );
                            }
                        }
                        Ty::Known(DataType::Float64)
                    }
                    BinaryOp::Add | BinaryOp::Sub | BinaryOp::Mul | BinaryOp::Mod => {
                        for t in [lt, rt] {
                            if t.non_numeric() {
                                self.diag(
                                    DiagCode::E101,
                                    &node.path,
                                    format!("arithmetic on a value of type {t}"),
                                );
                            }
                        }
                        if lt.is_known(DataType::Float64) || rt.is_known(DataType::Float64) {
                            Ty::Known(DataType::Float64)
                        } else if lt.is_known(DataType::Int64) && rt.is_known(DataType::Int64) {
                            Ty::Known(DataType::Int64)
                        } else {
                            Ty::Unknown
                        }
                    }
                }
            }
            Expr::Func { name, args } => self.type_func(name, args, node),
            Expr::IsNull { expr, .. } => {
                self.type_expr(expr, node);
                Ty::Known(DataType::Bool)
            }
            Expr::InList { expr, list, .. } => {
                let t = self.type_expr(expr, node);
                for item in list {
                    let it = self.type_expr(item, node);
                    if let (Some(a), Some(b)) = (t.known(), it.known()) {
                        if !comparable(a, b) {
                            // The kernel silently skips incomparable
                            // items — never a runtime error, but the item
                            // can never match either.
                            self.diag(
                                DiagCode::W005,
                                &node.path,
                                format!("IN list item of type {b} can never match a {a} value"),
                            );
                        }
                    }
                }
                Ty::Known(DataType::Bool)
            }
            Expr::Between { expr, low, high, .. } => {
                let t = self.type_expr(expr, node);
                let lo = self.type_expr(low, node);
                let hi = self.type_expr(high, node);
                for bound in [lo, hi] {
                    if let (Some(a), Some(b)) = (t.known(), bound.known()) {
                        if !comparable(a, b) {
                            self.diag(
                                DiagCode::E106,
                                &node.path,
                                format!("BETWEEN mixes {a} with {b}"),
                            );
                        }
                    }
                }
                Ty::Known(DataType::Bool)
            }
            Expr::Case { branches, else_value } => {
                let mut out: Option<Ty> = None;
                let mut mixed = false;
                let mut unify = |t: Ty, out: &mut Option<Ty>, mixed: &mut bool| {
                    *out = Some(match (*out, t) {
                        (None, t) => t,
                        (Some(a), b) if a == b => a,
                        (Some(a), b) => {
                            let num = |x: Ty| {
                                matches!(
                                    x,
                                    Ty::Known(DataType::Int64) | Ty::Known(DataType::Float64)
                                )
                            };
                            if num(a) && num(b) {
                                Ty::Known(DataType::Float64)
                            } else {
                                if a != Ty::Unknown && b != Ty::Unknown {
                                    *mixed = true;
                                }
                                Ty::Unknown
                            }
                        }
                    });
                };
                for (cond, value) in branches {
                    let ct = self.type_expr(cond, node);
                    if ct.known().is_some() && !ct.is_known(DataType::Bool) {
                        // The row path's `matches!(…, Bool(true))` just
                        // never matches a non-boolean condition.
                        self.diag(
                            DiagCode::W006,
                            &node.path,
                            format!("CASE condition has type {ct} — this branch never matches"),
                        );
                    }
                    let vt = self.type_expr(value, node);
                    unify(vt, &mut out, &mut mixed);
                }
                if let Some(ev) = else_value {
                    let et = self.type_expr(ev, node);
                    unify(et, &mut out, &mut mixed);
                }
                if mixed {
                    // Whether this errors at run time depends on which
                    // branch materializes first — lint, don't reject.
                    self.diag(
                        DiagCode::W008,
                        &node.path,
                        "CASE branches mix incompatible types".to_string(),
                    );
                }
                out.unwrap_or(Ty::Unknown)
            }
        }
    }

    /// Type a scalar function call, mirroring the builtin dispatch order
    /// (builtins shadow UDFs) and every arity/argument-type check the
    /// runtime builtins enforce.
    fn type_func(&mut self, name: &str, args: &[Expr], node: &NodeInfo) -> Ty {
        let tys: Vec<Ty> = args.iter().map(|a| self.type_expr(a, node)).collect();
        match name {
            "coalesce" => {
                let mut out: Option<Ty> = None;
                for t in &tys {
                    out = Some(match (out, *t) {
                        (None, t) => t,
                        (Some(a), b) if a == b => a,
                        (Some(a), b) => {
                            let num = |x: Ty| {
                                matches!(
                                    x,
                                    Ty::Known(DataType::Int64) | Ty::Known(DataType::Float64)
                                )
                            };
                            if num(a) && num(b) {
                                Ty::Known(DataType::Float64)
                            } else {
                                Ty::Unknown
                            }
                        }
                    });
                }
                out.unwrap_or(Ty::Unknown)
            }
            "abs" => {
                if self.arity(tys.len() == 1, node, "abs expects 1 argument") {
                    self.check_numeric_arg(name, tys[0], node);
                    if tys[0].is_known(DataType::Int64) {
                        return Ty::Known(DataType::Int64);
                    }
                }
                Ty::Known(DataType::Float64)
            }
            "sqrt" | "exp" | "ln" | "log10" | "floor" | "ceil" => {
                if self.arity(tys.len() == 1, node, &format!("{name} expects 1 argument")) {
                    self.check_numeric_arg(name, tys[0], node);
                }
                Ty::Known(DataType::Float64)
            }
            "round" => {
                if self.arity(
                    tys.len() == 1 || tys.len() == 2,
                    node,
                    "round expects 1 or 2 arguments",
                ) {
                    self.check_numeric_arg(name, tys[0], node);
                    if tys.len() == 2 {
                        // The digits argument coerces floats; only
                        // strings/booleans fail.
                        if tys[1].non_numeric() {
                            self.diag(
                                DiagCode::E111,
                                &node.path,
                                format!("round digits argument has type {}", tys[1]),
                            );
                        }
                    }
                }
                Ty::Known(DataType::Float64)
            }
            "power" | "pow" => {
                if self.arity(tys.len() == 2, node, &format!("{name} expects 2 arguments")) {
                    self.check_numeric_arg(name, tys[0], node);
                    self.check_numeric_arg(name, tys[1], node);
                }
                Ty::Known(DataType::Float64)
            }
            "upper" | "lower" | "length" => {
                if self.arity(tys.len() == 1, node, &format!("{name} expects 1 argument")) {
                    // Strict: the runtime `str1` helper rejects every
                    // non-string, including numbers.
                    if tys[0].known().is_some() && !tys[0].is_known(DataType::Utf8) {
                        self.diag(
                            DiagCode::E111,
                            &node.path,
                            format!("{name} expects a VARCHAR, got {}", tys[0]),
                        );
                    }
                }
                if name == "length" {
                    Ty::Known(DataType::Int64)
                } else {
                    Ty::Known(DataType::Utf8)
                }
            }
            "substr" | "substring" => {
                if self.arity(tys.len() == 3, node, "substr expects (str, start, len)") {
                    if tys[0].known().is_some() && !tys[0].is_known(DataType::Utf8) {
                        self.diag(
                            DiagCode::E111,
                            &node.path,
                            format!("substr expects a VARCHAR, got {}", tys[0]),
                        );
                    }
                    // start/len go through `as_i64().unwrap_or(…)` at run
                    // time — wrong types never error, so no check here.
                }
                Ty::Known(DataType::Utf8)
            }
            "concat" => Ty::Known(DataType::Utf8),
            _ => {
                if AggFunc::from_name(name, self.udfs).is_some() {
                    // An aggregate call the planner did not lift into an
                    // Aggregate operator (e.g. inside JOIN ON) reaches
                    // the scalar dispatcher at run time and fails as
                    // unknown. (Checked after the builtin arms: a
                    // builtin shadows a same-named UDAF at run time.)
                    self.diag(
                        DiagCode::E113,
                        &node.path,
                        format!("aggregate {name}(…) is not allowed in a scalar position"),
                    );
                    Ty::Unknown
                } else if self.udfs.has_scalar(name) || self.udfs.has_vectorized(name) {
                    // No arity metadata is registered for UDFs; trust the
                    // declared return type.
                    self.udfs
                        .scalar_return_type(name)
                        .map(Ty::Known)
                        .unwrap_or(Ty::Unknown)
                } else {
                    self.diag(
                        DiagCode::E004,
                        &node.path,
                        format!("unknown function {name:?}"),
                    );
                    Ty::Unknown
                }
            }
        }
    }

    /// Record an `E110` arity diagnostic when `ok` is false; returns `ok`
    /// so callers can guard their argument-type checks on it.
    fn arity(&mut self, ok: bool, node: &NodeInfo, detail: &str) -> bool {
        if !ok {
            self.diag(DiagCode::E110, &node.path, detail.to_string());
        }
        ok
    }

    fn check_numeric_arg(&mut self, name: &str, t: Ty, node: &NodeInfo) {
        if t.non_numeric() {
            self.diag(
                DiagCode::E111,
                &node.path,
                format!("{name} expects a number, got {t}"),
            );
        }
    }

    /// Predicate lints: constant truth values (W001/W002) and
    /// comparisons against NULL literals (W003).
    fn lint_predicate(&mut self, predicate: &Expr, node: &NodeInfo) {
        match const_truth(predicate) {
            Some(true) => self.diag(
                DiagCode::W001,
                &node.path,
                "predicate is constant TRUE — the filter is a no-op".to_string(),
            ),
            Some(false) => self.diag(
                DiagCode::W002,
                &node.path,
                "predicate is constant FALSE/NULL — every row is dropped".to_string(),
            ),
            None => {}
        }
        let mut null_cmp = false;
        walk_expr(predicate, &mut |e| {
            if let Expr::Binary { op, left, right } = e {
                let is_cmp = matches!(
                    op,
                    BinaryOp::Eq
                        | BinaryOp::NotEq
                        | BinaryOp::Lt
                        | BinaryOp::LtEq
                        | BinaryOp::Gt
                        | BinaryOp::GtEq
                );
                if is_cmp
                    && (matches!(**left, Expr::Literal(Value::Null))
                        || matches!(**right, Expr::Literal(Value::Null)))
                {
                    null_cmp = true;
                }
            }
        });
        if null_cmp {
            self.diag(
                DiagCode::W003,
                &node.path,
                "comparison with NULL always yields NULL — use IS NULL".to_string(),
            );
        }
    }
}

/// Static truth value of a predicate, when decidable without data:
/// literal TRUE / FALSE / NULL (NULL drops like FALSE under WHERE).
fn const_truth(e: &Expr) -> Option<bool> {
    match e {
        Expr::Literal(Value::Bool(b)) => Some(*b),
        Expr::Literal(Value::Null) => Some(false),
        _ => None,
    }
}

fn walk_expr(e: &Expr, f: &mut impl FnMut(&Expr)) {
    fn inner(e: &Expr, f: &mut dyn FnMut(&Expr)) {
        f(e);
        match e {
            Expr::Unary { expr, .. } => inner(expr, f),
            Expr::Binary { left, right, .. } => {
                inner(left, f);
                inner(right, f);
            }
            Expr::Func { args, .. } => args.iter().for_each(|a| inner(a, f)),
            Expr::IsNull { expr, .. } => inner(expr, f),
            Expr::InList { expr, list, .. } => {
                inner(expr, f);
                list.iter().for_each(|a| inner(a, f));
            }
            Expr::Between { expr, low, high, .. } => {
                inner(expr, f);
                inner(low, f);
                inner(high, f);
            }
            Expr::Case { branches, else_value } => {
                for (c, v) in branches {
                    inner(c, f);
                    inner(v, f);
                }
                if let Some(e) = else_value {
                    inner(e, f);
                }
            }
            _ => {}
        }
    }
    inner(e, f)
}

/// Does a referenced name plausibly refer to this output column?
/// Case-insensitive on the whole name and on the bare suffix in either
/// direction (mirrors the resolver's qualified/bare matching).
fn name_matches(referenced: &str, out_name: &str) -> bool {
    if referenced.eq_ignore_ascii_case(out_name) {
        return true;
    }
    let bare = |s: &str| s.rsplit_once('.').map(|(_, b)| b.to_string());
    if let Some(b) = bare(referenced) {
        if b.eq_ignore_ascii_case(out_name) {
            return true;
        }
    }
    if let Some(b) = bare(out_name) {
        if b.eq_ignore_ascii_case(referenced) {
            return true;
        }
    }
    false
}

/// Union the parent's needed-name set with the columns referenced by
/// `exprs`; `None` (everything needed) is absorbing. A `Star` or
/// `__drop_hidden` marker also degrades to `None`.
fn extend_needed<'e>(
    needed: Option<&[String]>,
    exprs: impl IntoIterator<Item = &'e Expr>,
) -> Option<Vec<String>> {
    let mut out: Vec<String> = needed?.to_vec();
    for e in exprs {
        let mut star = false;
        walk_expr(e, &mut |x| {
            if matches!(x, Expr::Star) {
                star = true;
            }
            if let Expr::Func { name, .. } = x {
                if name == "__drop_hidden" {
                    star = true;
                }
            }
        });
        if star {
            return None;
        }
        let mut cols = Vec::new();
        e.referenced_columns(&mut cols);
        out.extend(cols);
    }
    Some(out)
}

/// Mirror of `exec::plan_alias`: the FROM-clause label a join side
/// qualifies colliding columns with.
fn plan_label(p: &Plan, default: &str) -> String {
    match p {
        Plan::Scan { table, alias } => alias.clone().unwrap_or_else(|| table.clone()),
        Plan::TableFunc { name, alias, .. } => alias.clone().unwrap_or_else(|| name.clone()),
        Plan::Filter { input, .. } | Plan::Limit { input, .. } | Plan::Sort { input, .. } => {
            plan_label(input, default)
        }
        _ => default.to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{Column, Field, RowSet, Schema};

    fn catalog() -> Catalog {
        let cat = Catalog::new();
        cat.register(
            "t",
            RowSet::new(
                Schema::new(vec![
                    Field::new("a", DataType::Int64),
                    Field::new("b", DataType::Float64),
                    Field::new("s", DataType::Utf8),
                    Field::new("c", DataType::Bool),
                ]),
                vec![
                    Column::from_i64(vec![1, 2, 3]),
                    Column::from_f64(vec![1.5, 2.5, 3.5]),
                    Column::from_strings(vec!["x".into(), "y".into(), "z".into()]),
                    Column::from_bools(vec![true, false, true]),
                ],
            )
            .unwrap(),
        );
        cat
    }

    fn analyze(sql: &str) -> Analysis {
        analyze_sql(sql, &catalog(), &UdfRegistry::new())
    }

    fn codes(a: &Analysis) -> Vec<&'static str> {
        a.diagnostics.iter().map(|d| d.code.as_str()).collect()
    }

    #[test]
    fn clean_query_analyzes_clean() {
        let a = analyze("SELECT a + 1 AS a1, upper(s) AS u FROM t WHERE b > 1.0");
        assert!(a.is_ok(), "{}", a.render());
        assert_eq!(
            a.schema,
            vec![
                ("a1".to_string(), Ty::Known(DataType::Int64)),
                ("u".to_string(), Ty::Known(DataType::Utf8)),
            ]
        );
        assert!(a.est_rows >= 1);
        assert_eq!(a.est_scan_rows, 3);
    }

    #[test]
    fn unknown_column_carries_path() {
        let a = analyze("SELECT nope FROM t WHERE a > 0");
        assert!(!a.is_ok());
        let d = a.errors().next().unwrap();
        assert_eq!(d.code, DiagCode::E001);
        assert_eq!(d.path, "Scan(t) → Filter → Project");
    }

    #[test]
    fn unknown_table_does_not_cascade() {
        let a = analyze("SELECT x, y FROM missing WHERE z > 0");
        let c = codes(&a);
        assert_eq!(c, vec!["E003"], "{}", a.render());
    }

    #[test]
    fn type_errors_reject() {
        for (sql, code) in [
            ("SELECT a + s FROM t", "E101"),
            ("SELECT s < a FROM t", "E102"),
            ("SELECT a FROM t WHERE c AND s = 'x' AND a AND c", "E103"),
            ("SELECT NOT s FROM t", "E104"),
            ("SELECT -s FROM t", "E105"),
            ("SELECT a FROM t WHERE s BETWEEN 1 AND 2", "E106"),
            ("SELECT substr(s) FROM t", "E110"),
            ("SELECT upper(a) FROM t", "E111"),
            ("SELECT nosuchfn(a) FROM t", "E004"),
            ("SELECT sum(s) FROM t", "E120"),
            ("SELECT sum() FROM t", "E121"),
            ("SELECT a FROM t WHERE a + 1", "E130"),
        ] {
            let a = analyze(sql);
            assert!(
                a.errors().any(|d| d.code.as_str() == code),
                "{sql}: expected {code}, got {:?}",
                codes(&a)
            );
        }
    }

    #[test]
    fn lints_do_not_reject() {
        for (sql, code) in [
            ("SELECT a FROM t WHERE true", "W001"),
            ("SELECT a FROM t WHERE false", "W002"),
            ("SELECT a FROM t WHERE a = NULL", "W003"),
            ("SELECT a FROM t WHERE a IN (1, 'x')", "W005"),
            ("SELECT CASE WHEN a THEN 1 ELSE 2 END FROM t", "W006"),
            ("SELECT CASE WHEN c THEN 1 ELSE s END FROM t", "W008"),
        ] {
            let a = analyze(sql);
            assert!(a.is_ok(), "{sql}: rejected: {}", a.render_errors());
            assert!(
                a.diagnostics.iter().any(|d| d.code.as_str() == code),
                "{sql}: expected {code}, got {:?}",
                codes(&a)
            );
        }
    }

    #[test]
    fn unused_subquery_column_lints_w004() {
        let a = analyze("SELECT a1 FROM (SELECT a + 1 AS a1, b + 1.0 AS b1 FROM t) q");
        assert!(a.is_ok());
        assert!(
            a.diagnostics
                .iter()
                .any(|d| d.code == DiagCode::W004 && d.message.contains("b1")),
            "{}",
            a.render()
        );
    }

    #[test]
    fn aggregate_schema_and_estimates() {
        let a = analyze("SELECT s, count(*) AS n, avg(a) AS m FROM t GROUP BY s");
        assert!(a.is_ok(), "{}", a.render());
        assert_eq!(
            a.schema,
            vec![
                ("s".to_string(), Ty::Known(DataType::Utf8)),
                ("n".to_string(), Ty::Known(DataType::Int64)),
                ("m".to_string(), Ty::Known(DataType::Float64)),
            ]
        );
        assert!(a.est_rows <= 3);
        assert!(a.cold_bytes_hint() > 0);
    }

    #[test]
    fn join_collision_qualifies_and_resolves() {
        let cat = catalog();
        cat.register(
            "u",
            RowSet::new(
                Schema::new(vec![
                    Field::new("a", DataType::Int64),
                    Field::new("v", DataType::Float64),
                ]),
                vec![
                    Column::from_i64(vec![1, 2]),
                    Column::from_f64(vec![0.5, 0.25]),
                ],
            )
            .unwrap(),
        );
        let a = analyze_sql(
            "SELECT t.a, v FROM t JOIN u ON t.a = u.a",
            &cat,
            &UdfRegistry::new(),
        );
        assert!(a.is_ok(), "{}", a.render());
        // Bare `a` over the collided join schema is ambiguous.
        let a = analyze_sql(
            "SELECT a FROM t JOIN u ON t.a = u.a",
            &cat,
            &UdfRegistry::new(),
        );
        assert!(a.errors().any(|d| d.code == DiagCode::E002), "{}", a.render());
    }

    #[test]
    fn parse_and_plan_failures_are_coded() {
        let a = analyze("SELEC nope");
        assert_eq!(codes(&a), vec!["E000"]);
        let a = analyze("SELECT a FROM t WHERE sum(a) > 1");
        assert_eq!(codes(&a), vec!["E010"]);
    }

    #[test]
    fn fragment_report_present() {
        let a = analyze(
            "SELECT k2, count(*) AS n FROM \
             (SELECT a + 1 AS k2 FROM t WHERE b > 1.0) q GROUP BY k2",
        );
        assert!(a.is_ok(), "{}", a.render());
        assert!(
            a.fragments.iter().any(|f| f.fused),
            "{:?}",
            a.fragments
        );
        // Bare scan-filter chain: candidate declined with a reason.
        let a = analyze("SELECT a, b FROM t WHERE b > 1.0");
        assert!(a.fragments.iter().any(|f| !f.fused && !f.reason.is_empty()));
    }

    #[test]
    fn estimator_hint_scales_with_schema_width() {
        let narrow = analyze("SELECT a FROM t");
        let wide = analyze("SELECT a, b, s, s || s AS s2 FROM t");
        assert!(wide.cold_bytes_hint() > narrow.cold_bytes_hint());
    }

    #[test]
    fn order_by_hidden_column_still_resolves() {
        let a = analyze("SELECT a + 1 AS a1 FROM t ORDER BY s LIMIT 2");
        assert!(a.is_ok(), "{}", a.render());
        assert_eq!(a.schema.len(), 1);
        assert_eq!(a.est_rows, 2);
    }

    #[test]
    fn select_star_passthrough() {
        let a = analyze("SELECT * FROM t");
        assert!(a.is_ok());
        assert_eq!(a.schema.len(), 4);
        assert_eq!(a.est_rows, 3);
    }

    #[test]
    fn from_less_select_uses_dual() {
        let a = analyze("SELECT 1 + 2 AS three");
        assert!(a.is_ok(), "{}", a.render());
        assert_eq!(a.est_rows, 1);
        assert!(a.schema[0].0 == "three");
    }
}
