//! Row redistribution for UDFs (§IV.C, Fig. 6) — the exchange operator.
//!
//! "During the execution stage, the source rowset operator will
//! redistribute the rows across all Python interpreter processes in
//! different virtual warehouse nodes using a round-robin approach,
//! ensuring full parallelism. ... we examine the workload's per-row
//! execution time from historical stats and define a threshold (T) to
//! determine whether it is worth row level redistribution. Furthermore,
//! to reduce the networking calls for redistributing rows, ... we buffer
//! the rows and asynchronously redistribute them to the target rowset
//! operator when the receiver finishes the previous batch of work."
//!
//! Implementation notes:
//! - `Local` assigns each partition's rows only to the interpreter
//!   processes of its *own* node — the skew-preserving baseline.
//! - `RoundRobin` deals buffered batches across *all* processes on all
//!   nodes; cross-node batches pay the pool's transport cost.
//! - `Auto` consults historical per-row cost (falling back to the UDF's
//!   static estimate) against the threshold T — the production policy
//!   (applied to 37.6 % of UDF queries per the paper).
//! - Asynchrony + receiver pacing come from the pool's bounded queues: a
//!   sender never gets more than `queue_depth` batches ahead of a slow
//!   process.
//!
//! The engine's *internal* exchange rides the same codec:
//! [`ship_columns`] round-trips a node span (or, since PR 10, a shuffle
//! partition's representative key rows — see
//! `exec::dispatch_partitions`) through [`WireBatch`] and charges the
//! transport with the **actual encoded byte count**, so every wire-byte
//! statistic and A8/A15 ablation row reflects what a real network hop
//! would carry.

use std::sync::mpsc;

use anyhow::{anyhow, Result};

use crate::types::{Column, Field, RowSet, Value, WireBatch};
use crate::warehouse::{Batch, InterpreterPool, TransportCost};

/// Ship a contiguous row span of loose columns to a warehouse node
/// through the columnar wire codec: encode once from the source buffers,
/// pay the transport cost for the encoded bytes as real CPU on the
/// receiving (calling) thread, and decode into the node-local copy the
/// remote workers will compute on. Returns the decoded span and the wire
/// bytes charged. This is the same payload path UDF batches take through
/// the interpreter pool (§III.B / §IV.C); the engine's node dispatch
/// uses it to spread operator morsels across nodes.
pub fn ship_columns(
    fields: &[Field],
    cols: &[&Column],
    offset: usize,
    len: usize,
    transport: TransportCost,
) -> Result<(RowSet, u64)> {
    let wire = WireBatch::encode_columns(fields, cols, offset, len);
    let bytes = wire.wire_len() as u64;
    transport.charge_cpu(bytes);
    Ok((wire.decode()?, bytes))
}

/// Redistribution policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExchangeMode {
    /// Node-local processing only (baseline).
    Local,
    /// Always redistribute round-robin across every process.
    RoundRobin,
    /// Redistribute iff historical per-row cost exceeds `threshold_ns`.
    Auto,
}

/// Exchange configuration.
#[derive(Debug, Clone, Copy)]
pub struct ExchangeConfig {
    /// Redistribution policy.
    pub mode: ExchangeMode,
    /// Rows per buffered batch (the paper's buffering knob B).
    pub batch_rows: usize,
    /// Per-row cost threshold T (nanoseconds) for `Auto`.
    pub threshold_ns: u64,
}

impl Default for ExchangeConfig {
    fn default() -> Self {
        Self { mode: ExchangeMode::Auto, batch_rows: 256, threshold_ns: 2_000 }
    }
}

/// Report of one exchange execution (feeds Fig. 6's production table).
#[derive(Debug, Clone, Default)]
pub struct ExchangeReport {
    /// Whether the policy decided to redistribute across all nodes.
    pub redistributed: bool,
    /// Total batches shipped.
    pub batches: usize,
    /// Batches delivered to a process on a different node.
    pub remote_batches: usize,
    /// Total input rows across all partitions.
    pub rows: usize,
    /// Total column-major wire bytes encoded for the batches.
    pub wire_bytes: usize,
}

/// Decide whether `Auto` should redistribute this UDF, per §IV.C.
pub fn should_redistribute(
    udf: &str,
    pool: &InterpreterPool,
    registry: &crate::udf::UdfRegistry,
    threshold_ns: u64,
) -> bool {
    let hist = pool.stats().row_cost_ns(udf);
    let est = hist.unwrap_or_else(|| {
        registry
            .scalar(udf)
            .map(|u| u.est_row_cost_ns as f64)
            .unwrap_or(0.0)
    });
    est > threshold_ns as f64
}

/// Run `udf` over partitioned input through the interpreter pool.
///
/// `partitions[i]` is the rowset resident on node `i % nodes` (the source
/// rowset operator's placement). Returns one output column per partition,
/// row-aligned with that partition's input, plus the exchange report.
pub fn run_udf_exchange(
    partitions: &[RowSet],
    udf: &str,
    pool: &InterpreterPool,
    registry: &crate::udf::UdfRegistry,
    cfg: ExchangeConfig,
) -> Result<(Vec<Column>, ExchangeReport)> {
    let n_nodes = pool.config().nodes;
    let redistribute = match cfg.mode {
        ExchangeMode::Local => false,
        ExchangeMode::RoundRobin => true,
        ExchangeMode::Auto => should_redistribute(udf, pool, registry, cfg.threshold_ns),
    };

    let mut report = ExchangeReport {
        redistributed: redistribute,
        ..Default::default()
    };

    // Cut every partition into buffered batches, tagged with a global
    // sequence so results stitch back deterministically. Each batch is
    // encoded into the column-major wire format once, straight from the
    // partition's column buffers — no per-row `RowSet::row` round trips
    // and no intermediate sliced rowsets.
    struct Slot {
        partition: usize,
        offset: usize,
        len: usize,
    }
    let mut slots: Vec<Slot> = Vec::new();
    let mut batches: Vec<Batch> = Vec::new();
    for (pid, part) in partitions.iter().enumerate() {
        report.rows += part.num_rows();
        let mut off = 0;
        while off < part.num_rows() {
            let len = cfg.batch_rows.min(part.num_rows() - off);
            let seq = batches.len() as u64;
            slots.push(Slot { partition: pid, offset: off, len });
            let batch = Batch::from_range(seq, udf, part, off, len, pid % n_nodes);
            report.wire_bytes += batch.payload.wire_len();
            batches.push(batch);
            off += len;
        }
    }
    report.batches = batches.len();

    // Target selection.
    let (result_tx, result_rx) = mpsc::channel();
    let mut rr = 0usize;
    let total = batches.len();
    for batch in batches {
        let target = if redistribute {
            // Round-robin across ALL processes on all nodes.
            let t = rr % pool.total_procs();
            rr += 1;
            t
        } else {
            // Local: round-robin only among the origin node's processes.
            let local = pool.procs_on_node(batch.origin_node);
            if local.is_empty() {
                return Err(anyhow!("node {} has no processes", batch.origin_node));
            }
            let t = local[rr % local.len()];
            rr += 1;
            t
        };
        if pool.node_of(target) != batch.origin_node {
            report.remote_batches += 1;
        }
        // Bounded queues: this blocks when the target is saturated —
        // receiver-paced, asynchronous buffering per §IV.C.
        pool.submit(target, batch, result_tx.clone())?;
    }
    drop(result_tx);

    // Collect and stitch.
    let mut by_seq: Vec<Option<Vec<Value>>> = (0..total).map(|_| None).collect();
    for res in result_rx {
        let r = res?;
        by_seq[r.seq as usize] = Some(r.values);
    }
    let mut outputs: Vec<Vec<Value>> = partitions
        .iter()
        .map(|p| vec![Value::Null; p.num_rows()])
        .collect();
    for (slot, values) in slots.iter().zip(by_seq.into_iter()) {
        let values = values.ok_or_else(|| anyhow!("batch result missing"))?;
        if values.len() != slot.len {
            return Err(anyhow!(
                "batch returned {} values for {} rows",
                values.len(),
                slot.len
            ));
        }
        outputs[slot.partition][slot.offset..slot.offset + slot.len]
            .clone_from_slice(&values);
    }
    // The registry's declared return type is authoritative, so every
    // partition of one UDF column comes back with the same dtype and
    // empty / all-NULL partitions don't fall back to Float64 when the
    // UDF declares otherwise; value inference only covers UDFs with no
    // declared type. A declared Int64 widens to Float64 when any
    // partition produced a float — computed over ALL partitions so the
    // dtype stays consistent — matching the inline expression path
    // (`expr.rs` numeric coercion) and the UDAF finish rule instead of
    // silently truncating.
    let mut dt = registry
        .scalar_return_type(udf)
        .or_else(|| outputs.iter().flatten().find_map(Value::data_type))
        .unwrap_or(crate::types::DataType::Float64);
    if dt == crate::types::DataType::Int64
        && outputs.iter().flatten().any(|v| matches!(v, Value::Float(_)))
    {
        dt = crate::types::DataType::Float64;
    }
    let mut columns = Vec::with_capacity(outputs.len());
    for vals in &outputs {
        columns.push(Column::from_values(dt, vals)?);
    }
    Ok((columns, report))
}

/// Deterministic makespan model of one exchange execution.
///
/// Reproduces the paper's Fig. 6 trade-off independently of the bench
/// host's core count (this image has a single CPU, so thread wall clock
/// cannot reflect parallel capacity): batches are assigned exactly as
/// [`run_udf_exchange`] assigns them, each process accumulates
/// `rows × row_cost + transport(remote)`, and the makespan is the busiest
/// process — the straggler that determines query latency on a real
/// multi-node warehouse.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimulatedExchange {
    /// Busy time of the busiest process (the straggler / makespan).
    pub makespan_ns: u64,
    /// Sum of busy time over all processes.
    pub total_work_ns: u64,
    /// Batches that crossed a node boundary.
    pub remote_batches: usize,
    /// Total batches dealt.
    pub total_batches: usize,
}

/// Run the deterministic makespan model with the given shape and policy
/// (see [`SimulatedExchange`]).
#[allow(clippy::too_many_arguments)]
pub fn simulate_exchange(
    partition_rows: &[usize],
    row_cost_ns: u64,
    row_bytes: u64,
    nodes: usize,
    procs_per_node: usize,
    transport: crate::warehouse::TransportCost,
    cfg: ExchangeConfig,
    redistribute: bool,
) -> SimulatedExchange {
    let total_procs = nodes * procs_per_node;
    let mut per_proc = vec![0u64; total_procs];
    let mut rr = 0usize;
    let mut remote = 0usize;
    let mut total_batches = 0usize;
    for (pid, &rows) in partition_rows.iter().enumerate() {
        let origin = pid % nodes;
        let mut off = 0;
        while off < rows {
            let len = cfg.batch_rows.min(rows - off);
            let target = if redistribute {
                let t = rr % total_procs;
                rr += 1;
                t
            } else {
                let t = origin * procs_per_node + (rr % procs_per_node);
                rr += 1;
                t
            };
            let mut cost = len as u64 * row_cost_ns;
            if target / procs_per_node != origin {
                remote += 1;
                cost += transport.cost(len as u64 * row_bytes).as_nanos() as u64;
            }
            per_proc[target] += cost;
            total_batches += 1;
            off += len;
        }
    }
    SimulatedExchange {
        makespan_ns: per_proc.iter().copied().max().unwrap_or(0),
        total_work_ns: per_proc.iter().sum(),
        remote_batches: remote,
        total_batches,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{DataType, Field, Schema};
    use crate::udf::{UdfRegistry, UdfStatsStore};
    use crate::warehouse::{PoolConfig, TransportCost};
    use std::sync::Arc;
    use std::time::Duration;

    fn registry(row_cost_ns: u64) -> Arc<UdfRegistry> {
        let mut r = UdfRegistry::new();
        let udf = r.register_scalar(
            "work",
            DataType::Float64,
            Arc::new(move |args| {
                // Simulate genuine per-row compute.
                let mut acc = args[0].as_f64().unwrap_or(0.0);
                let iters = row_cost_ns / 10;
                for i in 0..iters {
                    acc = (acc + i as f64).sqrt() + 1.0;
                }
                Ok(Value::Float(acc))
            }),
        );
        udf.est_row_cost_ns = row_cost_ns;
        Arc::new(r)
    }

    fn pool(registry: Arc<UdfRegistry>) -> InterpreterPool {
        InterpreterPool::spawn(
            PoolConfig {
                nodes: 2,
                procs_per_node: 2,
                queue_depth: 2,
                transport: TransportCost {
                    per_call: Duration::from_micros(50),
                    ns_per_byte: 0.2,
                },
            },
            registry,
            Arc::new(UdfStatsStore::new()),
        )
    }

    fn partitions(sizes: &[usize]) -> Vec<RowSet> {
        sizes
            .iter()
            .enumerate()
            .map(|(p, &n)| {
                RowSet::new(
                    Schema::new(vec![Field::new("x", DataType::Float64)]),
                    vec![Column::from_f64(
                        (0..n).map(|i| (p * 1000 + i) as f64).collect(),
                    )],
                )
                .unwrap()
            })
            .collect()
    }

    #[test]
    fn every_row_processed_exactly_once_all_modes() {
        let reg = registry(500);
        let p = pool(reg.clone());
        let parts = partitions(&[100, 5, 37]);
        for mode in [ExchangeMode::Local, ExchangeMode::RoundRobin, ExchangeMode::Auto] {
            let cfg = ExchangeConfig { mode, batch_rows: 16, threshold_ns: 1 };
            let (cols, report) = run_udf_exchange(&parts, "work", &p, &reg, cfg).unwrap();
            assert_eq!(cols.len(), 3);
            assert_eq!(report.rows, 142);
            for (c, part) in cols.iter().zip(&parts) {
                assert_eq!(c.len(), part.num_rows());
                for i in 0..c.len() {
                    assert!(
                        !c.value(i).is_null(),
                        "{mode:?}: row {i} not computed"
                    );
                }
            }
        }
    }

    #[test]
    fn results_row_aligned_with_inputs() {
        let mut r = UdfRegistry::new();
        r.register_scalar(
            "ident",
            DataType::Float64,
            Arc::new(|args| Ok(args[0].clone())),
        );
        let reg = Arc::new(r);
        let p = pool(reg.clone());
        let parts = partitions(&[50, 20]);
        let cfg = ExchangeConfig {
            mode: ExchangeMode::RoundRobin,
            batch_rows: 7,
            threshold_ns: 0,
        };
        let (cols, _) = run_udf_exchange(&parts, "ident", &p, &reg, cfg).unwrap();
        for (pi, (c, part)) in cols.iter().zip(&parts).enumerate() {
            for i in 0..part.num_rows() {
                assert_eq!(
                    c.value(i),
                    part.column(0).value(i),
                    "partition {pi} row {i} misaligned"
                );
            }
        }
    }

    #[test]
    fn local_mode_never_sends_remote() {
        let reg = registry(100);
        let p = pool(reg.clone());
        let parts = partitions(&[64, 64]);
        let cfg = ExchangeConfig { mode: ExchangeMode::Local, batch_rows: 8, threshold_ns: 0 };
        let (_, report) = run_udf_exchange(&parts, "work", &p, &reg, cfg).unwrap();
        assert!(!report.redistributed);
        assert_eq!(report.remote_batches, 0);
    }

    #[test]
    fn round_robin_spreads_across_nodes() {
        let reg = registry(100);
        let p = pool(reg.clone());
        let parts = partitions(&[128, 0]); // all rows on node 0
        let cfg = ExchangeConfig {
            mode: ExchangeMode::RoundRobin,
            batch_rows: 8,
            threshold_ns: 0,
        };
        let (_, report) = run_udf_exchange(&parts, "work", &p, &reg, cfg).unwrap();
        assert!(report.redistributed);
        assert!(report.remote_batches > 0, "{report:?}");
    }

    #[test]
    fn auto_respects_threshold() {
        let reg = registry(10_000); // est. 10µs/row
        let p = pool(reg.clone());
        assert!(should_redistribute("work", &p, &reg, 2_000));
        assert!(!should_redistribute("work", &p, &reg, 50_000));
        // Unknown UDF: no history, no estimate → don't redistribute.
        assert!(!should_redistribute("mystery", &p, &reg, 2_000));
    }

    #[test]
    fn auto_uses_history_over_static_estimate() {
        let reg = registry(1); // static estimate says "cheap"
        let p = pool(reg.clone());
        // Feed history saying it's actually expensive.
        p.stats().record_batch("work", 100, 10_000_000); // 100µs/row
        assert!(should_redistribute("work", &p, &reg, 2_000));
    }

    #[test]
    fn skewed_load_benefits_from_redistribution() {
        // All rows on node 0; per-row work ≫ transfer cost. The makespan
        // proxy (max per-process busy time) must drop under round-robin —
        // robust even on single-core hosts where wall clock cannot show
        // parallel capacity.
        let reg = registry(40_000);
        let parts = partitions(&[600, 0]);
        let local_cfg =
            ExchangeConfig { mode: ExchangeMode::Local, batch_rows: 32, threshold_ns: 0 };
        let rr_cfg =
            ExchangeConfig { mode: ExchangeMode::RoundRobin, batch_rows: 32, threshold_ns: 0 };
        let makespan = |cfg: ExchangeConfig| {
            let p = pool(reg.clone());
            run_udf_exchange(&parts, "work", &p, &reg, cfg).unwrap();
            *p.busy_by_proc().iter().max().unwrap()
        };
        let local_ms = makespan(local_cfg);
        let rr_ms = makespan(rr_cfg);
        assert!(
            (rr_ms as f64) < local_ms as f64 * 0.75,
            "redistribution should cut the straggler: rr={rr_ms} local={local_ms}"
        );
    }

    #[test]
    fn simulated_exchange_matches_paper_shape() {
        let t = crate::warehouse::TransportCost::default();
        let cfg = ExchangeConfig { mode: ExchangeMode::Auto, batch_rows: 256, threshold_ns: 0 };
        // Skewed 4-partition layout, expensive UDF: redistribution wins.
        let skewed = [80_000usize, 5_000, 3_000, 2_000];
        let local = simulate_exchange(&skewed, 25_000, 64, 4, 2, t, cfg, false);
        let rr = simulate_exchange(&skewed, 25_000, 64, 4, 2, t, cfg, true);
        assert!(rr.makespan_ns < local.makespan_ns);
        assert!(rr.remote_batches > 0);
        assert_eq!(rr.total_batches, local.total_batches);
        // Balanced layout, cheap UDF: redistribution's overhead loses.
        let balanced = [10_000usize; 4];
        let local = simulate_exchange(&balanced, 300, 64, 4, 2, t, cfg, false);
        let rr = simulate_exchange(&balanced, 300, 64, 4, 2, t, cfg, true);
        assert!(
            rr.makespan_ns >= local.makespan_ns,
            "rr={} local={}",
            rr.makespan_ns,
            local.makespan_ns
        );
    }

    #[test]
    fn ship_columns_round_trips_span() {
        let parts = partitions(&[40]);
        let rs = &parts[0];
        let cols: Vec<&Column> = rs.columns.iter().collect();
        let (decoded, bytes) =
            ship_columns(&rs.schema.fields, &cols, 8, 16, TransportCost::default()).unwrap();
        assert_eq!(decoded, rs.slice(8, 16));
        assert!(bytes > 0);
    }

    #[test]
    fn empty_partitions_ok() {
        let reg = registry(100);
        let p = pool(reg.clone());
        let parts = partitions(&[0, 0]);
        let (cols, report) =
            run_udf_exchange(&parts, "work", &p, &reg, ExchangeConfig::default()).unwrap();
        assert_eq!(report.rows, 0);
        assert_eq!(cols.len(), 2);
        assert_eq!(cols[0].len(), 0);
    }
}
