//! Per-node pipeline fragments: the planner side.
//!
//! PR 4 distributed each *operator* across warehouse nodes but
//! materialized every intermediate on the leader, so a
//! scan→filter→project→aggregate query shipped the same remote spans
//! back and forth once per operator — exactly the leader bottleneck the
//! paper's elastic data-engineering path avoids, and the core lesson of
//! pipelined distributed execution (Cylon, arXiv:2301.07896). This
//! module walks the [`Plan`] tree and groups the morsel-splittable
//! operators into **fragments**: a chain of `Filter`/`Project` stages
//! over one materialized source, optionally capped by a pipeline
//! breaker's node-local half —
//!
//! - **aggregate pre-partials** (breaker: the leader's partial merge),
//! - **sort run generation** (breaker: the leader's k-way merge),
//! - or no cap at all (breaker: the exchange back to the leader).
//!
//! The executor (`exec::exec_fragment`) ships each remote node its span
//! of the fragment's *input* columns **once**, runs the whole stage
//! chain node-locally on the work-stealing morsel scheduler, and
//! returns only the fragment outputs (filtered/projected segments,
//! aggregate partials, sorted runs) to the leader for the breaker step.
//! The join probe — already dispatched as a single-shipment operator by
//! PR 4 — is reported as its own fragment (breaker: the leader-built
//! broadcast build table).
//!
//! Eligibility is conservative *in shipment counts*: a fragment only
//! forms when fusing saves (or at worst matches) the number of
//! per-operator shipments, and never when an expression calls a
//! batch-dependent *vectorized* UDF (splitting would move its batch
//! boundary). Shipment counts are not bytes, though: a fragment ships
//! its whole input span at pre-filter cardinality, while the legacy
//! path ships downstream operators' columns at *post-filter*
//! cardinality — so under a highly selective filter a fused chain can
//! ship more bytes than operator-at-a-time dispatch even while
//! shipping fewer times (selectivity is unknown at plan time; feeding
//! recorded per-query selectivity into this gate is future work, see
//! ROADMAP). On the moderate selectivities typical of analytic scans
//! the single shipment wins, which the A11 ablation and the
//! wire-bytes differential test quantify. Everything that declines
//! falls back to the PR 4 operator-at-a-time dispatch, which
//! `ExecContext::fragments = false` (`SNOWPARK_FRAGMENTS=0`) also pins
//! wholesale as the `pipeline_fragments` (A11) ablation baseline.
//!
//! Error-ordering caveat (extending the one the batched projection
//! already carries): when *different* fused operators would fail at
//! different rows, the surfaced error is the earliest *morsel's*, not
//! the upstream-most operator's — a fragment evaluates its whole chain
//! morsel-at-a-time instead of operator-at-a-time. The first error in
//! morsel order still wins deterministically.
//!
//! Retry safety: fragments are dispatched through the same
//! `exec::dispatch_morsels` funnel as operator-at-a-time spans, so the
//! fault-recovery layer (`fault::FaultScope` — span retry, node
//! blacklisting, reroute to survivors) applies to them unchanged. A
//! fragment attempt is a pure function of `(target, span)` — it
//! re-encodes its input columns from the leader's materialized source
//! and recomputes every stage — so a retried or rerouted span is
//! bit-identical to the first attempt at any shape.
//!
//! **The shuffle boundary** (PR 10): with `ExecContext::shuffle` on at
//! multi-node shapes, a fragment's breaker gains a second exchange hop —
//! its own breaker kind, reported as a `"shuffle"` op on the fragment.
//! After the morsel dispatch returns the node-local halves, the leader
//! *routes* instead of merging: each global group (aggregate cap) is
//! hash-partitioned to an owning node that folds its groups' partials
//! in morsel order via `exec::dispatch_partitions` (per-partition task
//! dispatch with the same retry/reroute recovery as span dispatch — a
//! blacklisted owner's partitions redistribute to survivors), and sorted
//! runs climb a binary node tree instead of fanning into a flat leader
//! k-way merge. First-seen group order survives repartitioning because
//! partition routing happens *after* the leader assigns global dense
//! ids, and within a partition groups stay in ascending global-id
//! order. `SNOWPARK_SHUFFLE=0` pins the flat leader-merge breaker as
//! the differential baseline.

use crate::sql::ast::{Expr, OrderKey};
use crate::udf::UdfRegistry;

use super::exec::morsel_splittable;
use super::plan::AggCall;
use super::rewrite::PhysicalPlan as Plan;

/// One pipelined (non-breaking) operator inside a fragment, applied
/// per morsel over the node-local span in row order.
pub(crate) enum FragStage<'p> {
    /// `WHERE`/`HAVING`-style row filter.
    Filter(&'p Expr),
    /// Projection (may contain `*` and the planner's `__drop_hidden`
    /// marker, both of which expand against the working schema).
    Project(&'p [(Expr, String)]),
}

/// The pipeline breaker a fragment feeds, i.e. what each morsel returns
/// to the leader.
pub(crate) enum FragCap<'p> {
    /// No breaker: the filtered/projected column segments themselves
    /// travel back and concatenate in morsel order.
    Chain,
    /// Aggregate pre-partials; the leader re-keys representatives into
    /// global dense group ids and folds the partials.
    Aggregate {
        /// Group-key expressions (over the working schema).
        group: &'p [(Expr, String)],
        /// Aggregate calls.
        aggs: &'p [AggCall],
    },
    /// Sorted (optionally top-k-truncated) run generation; the leader
    /// k-way merges the runs under the index-tiebroken total order.
    Sort {
        /// ORDER BY keys (over the working schema).
        keys: &'p [OrderKey],
        /// Top-k bound when a `LIMIT` rides the sort.
        limit: Option<usize>,
        /// The hidden-column-dropping projection the planner inserts
        /// above the sort, run on the leader over the merged k rows.
        tail: Option<&'p [(Expr, String)]>,
    },
}

/// A planned fragment: stages applied bottom-up over `source`'s rows,
/// feeding `cap`.
pub(crate) struct Fragment<'p> {
    /// Pipelined stages in application order (deepest first).
    pub stages: Vec<FragStage<'p>>,
    /// The breaker the fragment feeds.
    pub cap: FragCap<'p>,
    /// The subtree that materializes the fragment's input rows.
    pub source: &'p Plan,
}

/// Does this stage dispatch (and therefore ship remote spans) under the
/// PR 4 operator-at-a-time path? Filters ship when their predicate is
/// morsel-splittable; projections ship when at least one expression is.
fn stage_ships(stage: &FragStage, udfs: &UdfRegistry) -> bool {
    match stage {
        FragStage::Filter(pred) => morsel_splittable(pred, udfs),
        FragStage::Project(exprs) => {
            exprs.iter().any(|(e, _)| morsel_splittable(e, udfs))
        }
    }
}

/// Does the expression (or any sub-expression) call a registered
/// *vectorized* UDF? Those are batch-at-a-time and may be
/// batch-dependent, so a fragment must not move their batch boundary.
fn stage_has_vectorized(stage: &FragStage, udfs: &UdfRegistry) -> bool {
    match stage {
        FragStage::Filter(pred) => super::exec::has_vectorized_udf(pred, udfs),
        FragStage::Project(exprs) => exprs
            .iter()
            .any(|(e, _)| super::exec::has_vectorized_udf(e, udfs)),
    }
}

/// Collect the maximal `Filter`/`Project` chain under `plan`, returning
/// the stages in application order plus the source subtree below them.
fn collect_chain<'p>(mut plan: &'p Plan) -> (Vec<FragStage<'p>>, &'p Plan) {
    let mut rev: Vec<FragStage<'p>> = Vec::new();
    loop {
        match plan {
            Plan::Filter { input, predicate } => {
                rev.push(FragStage::Filter(predicate));
                plan = input;
            }
            Plan::Project { input, exprs } => {
                rev.push(FragStage::Project(exprs));
                plan = input;
            }
            other => {
                rev.reverse();
                return (rev, other);
            }
        }
    }
}

impl<'p> Fragment<'p> {
    /// Extract the fragment rooted at `plan`, if one should form there.
    ///
    /// Fragment roots and their rules:
    /// - `Aggregate` → stages = the chain below it (possibly empty);
    ///   always worth fusing (the aggregate alone ships its key/arg
    ///   columns under operator-at-a-time dispatch).
    /// - `Sort`, `Limit(Sort)`, `Limit(Project(Sort))` → sort cap (with
    ///   the top-k bound and the hidden-column tail projection); needs
    ///   at least one `Project` stage (so the output column set is an
    ///   explicit projection, not the full input) and at least one
    ///   shipping stage.
    /// - `Project` → capless chain; needs ≥ 2 shipping ops to beat the
    ///   per-operator dispatch on wire bytes.
    ///
    /// Any vectorized-UDF call in a stage or cap expression declines the
    /// whole fragment (the legacy dispatch preserves whole-input
    /// evaluation for those).
    pub(crate) fn extract(plan: &'p Plan, udfs: &UdfRegistry) -> Option<Fragment<'p>> {
        let (stages, cap, source) = match plan {
            Plan::Aggregate { input, group, aggs } => {
                let (stages, source) = collect_chain(input);
                let cap_vectorized = group
                    .iter()
                    .any(|(e, _)| super::exec::has_vectorized_udf(e, udfs))
                    || aggs.iter().any(|a| {
                        a.args
                            .iter()
                            .any(|e| super::exec::has_vectorized_udf(e, udfs))
                    });
                if cap_vectorized {
                    return None;
                }
                (stages, FragCap::Aggregate { group, aggs }, source)
            }
            Plan::Sort { input, keys } => {
                Self::extract_sort(input, keys, None, None, udfs)?
            }
            Plan::Limit { input, n } => match input.as_ref() {
                Plan::Sort { input: sort_input, keys } => {
                    Self::extract_sort(sort_input, keys, Some(*n), None, udfs)?
                }
                Plan::Project { input: proj_input, exprs }
                    if matches!(proj_input.as_ref(), Plan::Sort { .. }) =>
                {
                    let Plan::Sort { input: sort_input, keys } = proj_input.as_ref()
                    else {
                        unreachable!("guarded by matches! above");
                    };
                    Self::extract_sort(sort_input, keys, Some(*n), Some(exprs), udfs)?
                }
                _ => return None,
            },
            Plan::Project { input, exprs } => {
                let (mut stages, source) = collect_chain(input);
                stages.push(FragStage::Project(exprs));
                let ships =
                    stages.iter().filter(|s| stage_ships(s, udfs)).count();
                if ships < 2 {
                    return None;
                }
                (stages, FragCap::Chain, source)
            }
            _ => return None,
        };
        if stages.iter().any(|s| stage_has_vectorized(s, udfs)) {
            return None;
        }
        Some(Fragment { stages, cap, source })
    }

    #[allow(clippy::type_complexity)]
    fn extract_sort(
        input: &'p Plan,
        keys: &'p [OrderKey],
        limit: Option<usize>,
        tail: Option<&'p [(Expr, String)]>,
        udfs: &UdfRegistry,
    ) -> Option<(Vec<FragStage<'p>>, FragCap<'p>, &'p Plan)> {
        if limit == Some(0) {
            // LIMIT 0 short-circuits on the legacy path without sorting.
            return None;
        }
        if keys
            .iter()
            .any(|k| super::exec::has_vectorized_udf(&k.expr, udfs))
        {
            return None;
        }
        let (stages, source) = collect_chain(input);
        let has_project = stages
            .iter()
            .any(|s| matches!(s, FragStage::Project(_)));
        let ships = stages.iter().filter(|s| stage_ships(s, udfs)).count();
        if !has_project || ships < 1 {
            // Without an explicit projection the fragment would have to
            // ship every input column to reproduce the output; the
            // legacy sort ships only its key columns — cheaper.
            return None;
        }
        Some((stages, FragCap::Sort { keys, limit, tail }, source))
    }

    /// Prepend a filter stage (an embedded scan predicate being shipped
    /// with the fragment to remote spans instead of materialized on the
    /// leader). The predicate borrows from the same plan as every other
    /// stage, so the fragment's lifetime is unchanged.
    pub(crate) fn with_prepended_filter(mut self, pred: &'p Expr) -> Fragment<'p> {
        self.stages.insert(0, FragStage::Filter(pred));
        self
    }

    /// Undo [`Fragment::with_prepended_filter`] when the ship plan
    /// declines and the caller falls back to leader-side evaluation.
    pub(crate) fn without_prepended_filter(mut self) -> Fragment<'p> {
        self.stages.remove(0);
        self
    }

    /// Operator names fused into this fragment, in execution order
    /// (for `QueryStats` fragment reporting).
    pub(crate) fn op_names(&self) -> Vec<&'static str> {
        let mut ops: Vec<&'static str> = self
            .stages
            .iter()
            .map(|s| match s {
                FragStage::Filter(_) => "filter",
                FragStage::Project(_) => "project",
            })
            .collect();
        match self.cap {
            FragCap::Chain => {}
            FragCap::Aggregate { .. } => ops.push("aggregate"),
            FragCap::Sort { .. } => ops.push("sort"),
        }
        ops
    }
}

/// One entry in the analyzer's fragment-eligibility report: a fusion
/// candidate root and whether — or why not — a fragment formed there.
#[derive(Debug, Clone)]
pub struct FuseNote {
    /// Operator names in the (actual or would-be) fused chain, in
    /// execution order, e.g. `["filter", "project", "aggregate"]`.
    pub ops: Vec<String>,
    /// Did a fragment form at this candidate?
    pub fused: bool,
    /// Why the candidate declined (empty when `fused`), mirroring the
    /// eligibility rules in [`Fragment::extract`].
    pub reason: String,
}

/// Walk the plan exactly as the executor does — try to form a fragment
/// at every node, recursing through whatever doesn't fuse — and return
/// one [`FuseNote`] per fusion candidate met along the way.
pub(crate) fn fuse_report(plan: &Plan, udfs: &UdfRegistry) -> Vec<FuseNote> {
    let mut notes = Vec::new();
    walk_report(plan, udfs, &mut notes);
    notes
}

fn chain_ops(stages: &[FragStage], cap: Option<&str>) -> Vec<String> {
    let mut ops: Vec<String> = stages
        .iter()
        .map(|s| match s {
            FragStage::Filter(_) => "filter".to_string(),
            FragStage::Project(_) => "project".to_string(),
        })
        .collect();
    if let Some(c) = cap {
        ops.push(c.to_string());
    }
    ops
}

fn walk_report(plan: &Plan, udfs: &UdfRegistry, notes: &mut Vec<FuseNote>) {
    if let Some(f) = Fragment::extract(plan, udfs) {
        notes.push(FuseNote {
            ops: f.op_names().iter().map(|s| s.to_string()).collect(),
            fused: true,
            reason: String::new(),
        });
        walk_report(f.source, udfs, notes);
        return;
    }
    match plan {
        Plan::Aggregate { input, group, aggs } => {
            // `extract` only declines an aggregate root over vectorized
            // UDF calls — in the cap expressions or in a fused stage.
            let (stages, source) = collect_chain(input);
            let cap_vectorized = group
                .iter()
                .any(|(e, _)| super::exec::has_vectorized_udf(e, udfs))
                || aggs.iter().any(|a| {
                    a.args
                        .iter()
                        .any(|e| super::exec::has_vectorized_udf(e, udfs))
                });
            let reason = if cap_vectorized {
                "vectorized UDF in a group/aggregate expression"
            } else {
                "vectorized UDF in a fused stage"
            };
            notes.push(FuseNote {
                ops: chain_ops(&stages, Some("aggregate")),
                fused: false,
                reason: reason.to_string(),
            });
            walk_report(source, udfs, notes);
        }
        Plan::Sort { input, keys } => decline_sort(input, keys, None, udfs, notes),
        Plan::Limit { input, n } => match input.as_ref() {
            Plan::Sort { input: sort_input, keys } => {
                decline_sort(sort_input, keys, Some(*n), udfs, notes)
            }
            Plan::Project { input: proj_input, .. }
                if matches!(proj_input.as_ref(), Plan::Sort { .. }) =>
            {
                let Plan::Sort { input: sort_input, keys } = proj_input.as_ref()
                else {
                    unreachable!("guarded by matches! above");
                };
                decline_sort(sort_input, keys, Some(*n), udfs, notes)
            }
            other => walk_report(other, udfs, notes),
        },
        Plan::Project { input, exprs } => {
            let (mut stages, source) = collect_chain(input);
            stages.push(FragStage::Project(exprs));
            let ships = stages.iter().filter(|s| stage_ships(s, udfs)).count();
            let reason = if ships < 2 {
                "fewer than 2 shipping stages — per-operator dispatch ships no more"
            } else {
                "vectorized UDF in a fused stage"
            };
            notes.push(FuseNote {
                ops: chain_ops(&stages, None),
                fused: false,
                reason: reason.to_string(),
            });
            walk_report(source, udfs, notes);
        }
        Plan::Filter { input, .. } => walk_report(input, udfs, notes),
        Plan::Join { left, right, .. } => {
            walk_report(left, udfs, notes);
            walk_report(right, udfs, notes);
        }
        Plan::Scan { .. } | Plan::TableFunc { .. } => {}
    }
}

fn decline_sort(
    input: &Plan,
    keys: &[OrderKey],
    limit: Option<usize>,
    udfs: &UdfRegistry,
    notes: &mut Vec<FuseNote>,
) {
    let (stages, source) = collect_chain(input);
    let has_project = stages.iter().any(|s| matches!(s, FragStage::Project(_)));
    let ships = stages.iter().filter(|s| stage_ships(s, udfs)).count();
    let reason = if limit == Some(0) {
        "LIMIT 0 short-circuits on the legacy path without sorting"
    } else if keys
        .iter()
        .any(|k| super::exec::has_vectorized_udf(&k.expr, udfs))
    {
        "vectorized UDF in a sort key"
    } else if !has_project {
        "no explicit projection below the sort — the legacy sort ships only its key columns"
    } else if ships < 1 {
        "no stage ships under operator-at-a-time dispatch"
    } else {
        "vectorized UDF in a fused stage"
    };
    notes.push(FuseNote {
        ops: chain_ops(&stages, Some("sort")),
        fused: false,
        reason: reason.to_string(),
    });
    if limit != Some(0) {
        walk_report(source, udfs, notes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sql::parse_query;
    use crate::types::DataType;

    fn plan(sql: &str) -> Plan {
        let logical =
            super::super::plan::plan_query(&parse_query(sql).unwrap(), &UdfRegistry::new())
                .unwrap();
        // Fragments form over the *physical* plan; these tests exercise
        // the structural lowering (no rewrite rules applied).
        super::super::rewrite::lower(&logical)
    }

    fn extract_in(plan: &Plan, udfs: &UdfRegistry) -> Option<Fragment<'_>> {
        Fragment::extract(plan, udfs)
    }

    /// Walk to the first node a fragment forms at (mirrors the
    /// executor, which tries every operator it recurses through).
    fn first_fragment_ops(plan: &Plan, udfs: &UdfRegistry) -> Option<Vec<&'static str>> {
        if let Some(f) = extract_in(plan, udfs) {
            return Some(f.op_names());
        }
        match plan {
            Plan::Filter { input, .. }
            | Plan::Project { input, .. }
            | Plan::Aggregate { input, .. }
            | Plan::Sort { input, .. }
            | Plan::Limit { input, .. } => first_fragment_ops(input, udfs),
            Plan::Join { left, right, .. } => first_fragment_ops(left, udfs)
                .or_else(|| first_fragment_ops(right, udfs)),
            _ => None,
        }
    }

    #[test]
    fn scan_filter_project_aggregate_forms_one_fragment() {
        let p = plan(
            "SELECT k2, COUNT(*) AS n, SUM(vv) AS s FROM \
             (SELECT k + 1 AS k2, v * 2.0 AS vv FROM t WHERE v > 10.0) s \
             GROUP BY k2",
        );
        let udfs = UdfRegistry::new();
        let ops = first_fragment_ops(&p, &udfs).expect("fragment");
        assert_eq!(ops, vec!["filter", "project", "aggregate"]);
    }

    #[test]
    fn bare_aggregate_is_a_fragment() {
        let p = plan("SELECT k, COUNT(*) AS n FROM t GROUP BY k");
        let udfs = UdfRegistry::new();
        let ops = first_fragment_ops(&p, &udfs).expect("fragment");
        assert_eq!(ops, vec!["aggregate"]);
    }

    #[test]
    fn chain_needs_two_shipping_stages() {
        let udfs = UdfRegistry::new();
        // Filter ships, projection of bare columns does not: no fragment
        // at the Project root (the legacy dispatch ships less).
        let p = plan("SELECT k, v FROM t WHERE v > 1.0");
        assert!(extract_in(&p, &udfs).is_none());
        // Both ship: fragment.
        let p = plan("SELECT k + 1 AS k1 FROM t WHERE v > 1.0");
        let f = extract_in(&p, &udfs).expect("fragment");
        assert_eq!(f.op_names(), vec!["filter", "project"]);
        assert!(matches!(f.cap, FragCap::Chain));
    }

    #[test]
    fn sort_needs_projection_and_shipping_stage() {
        let udfs = UdfRegistry::new();
        // Star-only sort: no projection stage below the sort.
        let p = plan("SELECT * FROM t ORDER BY v");
        assert!(first_fragment_ops(&p, &udfs).is_none());
        // Computed projection under ORDER BY ... LIMIT: sort fragment
        // with a top-k cap.
        let p = plan("SELECT k + 1 AS k1, v * 2.0 AS vv FROM t ORDER BY vv DESC LIMIT 5");
        let ops = first_fragment_ops(&p, &udfs).expect("fragment");
        assert_eq!(ops, vec!["project", "sort"]);
    }

    #[test]
    fn limit_zero_declines() {
        let udfs = UdfRegistry::new();
        // The executor meets LIMIT 0 at the Limit root (its legacy arm
        // short-circuits without touching the Sort below), so the
        // planner must decline there.
        let p = plan("SELECT k + 1 AS k1, v * 2.0 AS vv FROM t ORDER BY vv LIMIT 0");
        assert!(matches!(p, Plan::Limit { .. }));
        assert!(extract_in(&p, &udfs).is_none());
    }

    #[test]
    fn vectorized_udf_declines_fragment() {
        let mut udfs = UdfRegistry::new();
        udfs.register_vectorized(
            "vscale",
            DataType::Float64,
            std::sync::Arc::new(|rows| {
                Ok(rows.column(0).f64_data().unwrap().to_vec())
            }),
        );
        let p = plan(
            "SELECT k2, COUNT(*) AS n FROM \
             (SELECT vscale(v) AS k2 FROM t WHERE v > 1.0) s GROUP BY k2",
        );
        // The aggregate root's chain contains a vectorized UDF: no
        // fragment anywhere in this plan.
        assert!(first_fragment_ops(&p, &udfs).is_none());
        // The same shape without the vectorized call fragments fine.
        let p = plan(
            "SELECT k2, COUNT(*) AS n FROM \
             (SELECT v + 1.0 AS k2 FROM t WHERE v > 1.0) s GROUP BY k2",
        );
        assert!(first_fragment_ops(&p, &udfs).is_some());
    }

    #[test]
    fn fuse_report_mirrors_extract() {
        let udfs = UdfRegistry::new();
        // Fused aggregate chain: one fused note over the scan.
        let p = plan(
            "SELECT k2, COUNT(*) AS n FROM \
             (SELECT k + 1 AS k2 FROM t WHERE v > 10.0) s GROUP BY k2",
        );
        let notes = fuse_report(&p, &udfs);
        assert_eq!(notes.len(), 1, "{notes:?}");
        assert!(notes[0].fused);
        assert_eq!(notes[0].ops, vec!["filter", "project", "aggregate"]);
        // Declined chain: reason mirrors the ships<2 rule.
        let p = plan("SELECT k, v FROM t WHERE v > 1.0");
        let notes = fuse_report(&p, &udfs);
        assert_eq!(notes.len(), 1, "{notes:?}");
        assert!(!notes[0].fused);
        assert!(notes[0].reason.contains("shipping stages"), "{notes:?}");
        // Star-only sort declines with the no-projection reason.
        let p = plan("SELECT * FROM t ORDER BY v");
        let notes = fuse_report(&p, &udfs);
        assert!(
            notes.iter().any(|n| !n.fused && n.reason.contains("projection")),
            "{notes:?}"
        );
    }

    #[test]
    fn hidden_sort_projection_stays_on_the_leader() {
        let udfs = UdfRegistry::new();
        // ORDER BY a column outside the select list: the planner inserts
        // a hidden sort column + a dropping projection above the sort.
        // The fragment caps at the sort; the drop runs leader-side.
        let p = plan("SELECT k + 1 AS k1 FROM t WHERE v > 1.0 ORDER BY tag LIMIT 3");
        let ops = first_fragment_ops(&p, &udfs).expect("fragment");
        assert_eq!(ops, vec!["filter", "project", "sort"]);
    }
}
