//! Vectorized query engine — the substrate that executes the SQL emitted
//! by the DataFrame API (§III.A) and hosts the UDF operators whose row
//! streams the redistribution optimization (§IV.C) rebalances.
//!
//! Pull-based, batch-materializing operators over columnar `RowSet`s:
//! scan, filter, project, hash aggregate, hash join, sort, limit, UDF/UDTF
//! execution, and the exchange operator implementing row redistribution.
//! The hot operators are morsel-driven parallel: large inputs split into
//! contiguous row-range morsels dispatched across warehouse nodes
//! ([`ExecContext::nodes`], spans shipped through the columnar exchange)
//! and, within a node, run on the work-stealing scheduler in
//! [`morsel`], capped by [`ExecContext::parallelism`] (see `exec`
//! module docs). Morsel-splittable operator chains fuse into per-node
//! **pipeline fragments** ([`ExecContext::fragments`], planner in
//! `fragment`): each remote node receives its span of a fragment's
//! input columns once and returns only the fragment outputs (column
//! segments, aggregate partials, sorted runs) for the leader's
//! pipeline-breaker step. At multi-node shapes the breakers themselves
//! distribute ([`ExecContext::shuffle`], `SNOWPARK_SHUFFLE=0` pins the
//! leader-merge baseline): aggregate groups hash-partition to owning
//! nodes that fold their partials in place, sorted runs climb a binary
//! merge tree, and large join build sides build partitioned per node
//! instead of as a leader-built broadcast. Node-span dispatch is
//! fault-tolerant: under a
//! [`fault::FaultPlan`] a failed span retries with capped backoff,
//! repeat offenders are blacklisted and their spans reroute to
//! survivors (degrading to the leader), and a [`fault::CancelToken`]
//! bounds the whole statement with a deadline — outputs stay
//! byte-identical to the fault-free run (see [`fault`]).

mod analyze;
mod catalog;
mod config;
mod exec;
pub mod exchange;
mod expr;
pub mod fault;
mod fragment;
pub mod hash;
mod key;
pub mod morsel;
mod plan;
mod rewrite;
mod stats;

pub use analyze::{
    analysis_enabled, analyze_plan, analyze_sql, Analysis, DiagCode, Diagnostic, Severity, Ty,
};
pub use catalog::{parse_csv, Catalog};
pub use config::EngineConfig;
pub use fragment::FuseNote;
pub use exec::{
    default_fragments, default_nodes, default_parallelism, default_rewrite, default_shuffle,
    execute_plan, execute_plan_with_stats, run_sql, run_sql_with_stats, ExecContext,
    FragmentStats, OpStats, QueryStats, MORSEL_MIN_ROWS,
};
pub use fault::{CancelToken, DeadlineExceeded, FaultPlan, FaultScope, InjectedFault};
pub use morsel::{
    run_stealing, run_stealing_cancellable, ExecTally, NodeCounters, StealConfig, StealTally,
};
pub use expr::{
    eval_expr, eval_expr_rowwise, eval_predicate, eval_predicate_rowwise, eval_row,
    resolve_column,
};
pub use key::KeyValue;
pub use plan::{output_name, plan_query, AggCall, AggFunc, LogicalPlan, Plan};
pub use rewrite::{
    explain_plan, lower, rewrite_plan, PhysicalPlan, RewriteReport, RuleFire,
};
pub use stats::{ColumnStats, StatsStore, TableStats};
