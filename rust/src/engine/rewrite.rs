//! Cost-based plan rewriting: [`LogicalPlan`] → [`PhysicalPlan`].
//!
//! `plan_query` produces a purely logical tree; this module lowers it to
//! the physical tree the executor consumes, applying rule-based rewrites
//! costed against the catalog's per-table [`super::stats::StatsStore`]:
//!
//! - **constant-elim** — always-true literal conjuncts are dropped from
//!   filters (and an all-true filter is removed entirely).
//! - **predicate-pushdown** — a filter above a pure rename/literal
//!   projection moves below it, with output names substituted back to
//!   the underlying expressions.
//! - **join-pushdown** — single-side conjuncts of a filter above a join
//!   move below the join onto their side (left side under INNER and
//!   LEFT joins, right side under INNER only).
//! - **scan-embed** — a selective filter directly above a large scan is
//!   embedded into the scan so downstream exchange/fragment shipping
//!   sees post-filter cardinality.
//! - **projection-prune** — scans materialize only the columns the rest
//!   of the plan can observe (`PhysicalPlan::Scan::live`).
//! - **join-swap** — for INNER hash joins the smaller estimated side
//!   becomes the build side (`swap_build`).
//!
//! Every rule preserves byte-identical results *and* the query's
//! Ok/Err status. Because this engine's kernels raise type errors
//! per-row (a bad value that never reaches evaluation raises nothing),
//! any rule that changes which rows an expression sees first proves the
//! expression *total* — incapable of a value-dependent error — from the
//! schema and column statistics (see [`proven`]). Rules that cannot
//! complete a proof simply decline; declining is always correct.

use std::collections::{BTreeSet, HashMap};

use crate::sql::ast::{BinaryOp, Expr, JoinKind, OrderKey, UnaryOp};
use crate::types::{DataType, Field, Schema, Value};
use crate::udf::UdfRegistry;

use super::catalog::Catalog;
use super::exec::MORSEL_MIN_ROWS;
use super::expr::resolve_column;
use super::plan::{AggCall, LogicalPlan};
use super::stats::{TableStats, DEFAULT_SELECTIVITY};

/// A scan-embedded filter must be at least this selective (estimated)
/// before it is worth evaluating on the leader ahead of shipping.
const EMBED_MAX_SELECTIVITY: f64 = 0.05;

/// Physical plan: the operator tree the executor consumes.
///
/// Mirrors [`LogicalPlan`] shape-for-shape, plus the physical decisions
/// the rewriter makes: scans carry an optional embedded predicate and a
/// live-column set, joins carry the chosen build side.
#[derive(Debug, Clone)]
pub enum PhysicalPlan {
    /// Read a named table from the catalog.
    Scan {
        /// Catalog table name.
        table: String,
        /// FROM-clause alias, if any.
        alias: Option<String>,
        /// Pushed-down predicate evaluated on the leader right after the
        /// table snapshot, before any exchange/fragment shipping.
        predicate: Option<Expr>,
        /// Columns (ascending schema indices) the rest of the plan can
        /// observe; `None` keeps every column.
        live: Option<Vec<usize>>,
    },
    /// Invoke a table function (UDTF) with constant arguments.
    TableFunc {
        /// UDTF name (`__dual` is the hidden one-row table).
        name: String,
        /// Constant argument expressions.
        args: Vec<Expr>,
        /// FROM-clause alias, if any.
        alias: Option<String>,
    },
    /// Keep rows where the predicate is true (WHERE / HAVING).
    Filter {
        /// Input operator.
        input: Box<PhysicalPlan>,
        /// Boolean predicate (NULL ⇒ drop).
        predicate: Expr,
    },
    /// Compute output expressions (SELECT list).
    Project {
        /// Input operator.
        input: Box<PhysicalPlan>,
        /// (expression, output name) pairs.
        exprs: Vec<(Expr, String)>,
    },
    /// Hash aggregation.
    Aggregate {
        /// Input operator.
        input: Box<PhysicalPlan>,
        /// Group-key expressions with output names.
        group: Vec<(Expr, String)>,
        /// Aggregate calls.
        aggs: Vec<AggCall>,
    },
    /// Hash join (nested-loop when no equi keys).
    Join {
        /// Probe-side input.
        left: Box<PhysicalPlan>,
        /// Build-side input.
        right: Box<PhysicalPlan>,
        /// Inner or left outer.
        kind: JoinKind,
        /// Equi-key pairs (left expr, right expr).
        equi: Vec<(Expr, Expr)>,
        /// Residual predicate over the combined schema.
        residual: Option<Expr>,
        /// Build the hash table from the (smaller) left side instead of
        /// the right; pair order is restored so output bytes match the
        /// unswapped join exactly.
        swap_build: bool,
    },
    /// Sort by keys (top-k when directly under a Limit).
    Sort {
        /// Input operator.
        input: Box<PhysicalPlan>,
        /// ORDER BY keys.
        keys: Vec<OrderKey>,
    },
    /// Keep the first `n` rows.
    Limit {
        /// Input operator.
        input: Box<PhysicalPlan>,
        /// Row cap.
        n: usize,
    },
}

/// One rewrite-rule application.
#[derive(Debug, Clone)]
pub struct RuleFire {
    /// Rule name (`constant-elim`, `predicate-pushdown`, `join-pushdown`,
    /// `scan-embed`, `projection-prune`, `join-swap`).
    pub rule: &'static str,
    /// Human-readable description of what the rule did.
    pub detail: String,
}

/// Which rules fired while rewriting a plan, in application order.
#[derive(Debug, Clone, Default)]
pub struct RewriteReport {
    /// Rule applications, in the order they happened.
    pub fired: Vec<RuleFire>,
}

impl RewriteReport {
    fn fire(&mut self, rule: &'static str, detail: String) {
        self.fired.push(RuleFire { rule, detail });
    }
}

/// Structurally lower a logical plan to a physical plan with no rewrites:
/// no embedded predicates, all columns live, build side unchanged.
pub fn lower(plan: &LogicalPlan) -> PhysicalPlan {
    match plan {
        LogicalPlan::Scan { table, alias } => PhysicalPlan::Scan {
            table: table.clone(),
            alias: alias.clone(),
            predicate: None,
            live: None,
        },
        LogicalPlan::TableFunc { name, args, alias } => PhysicalPlan::TableFunc {
            name: name.clone(),
            args: args.clone(),
            alias: alias.clone(),
        },
        LogicalPlan::Filter { input, predicate } => PhysicalPlan::Filter {
            input: Box::new(lower(input)),
            predicate: predicate.clone(),
        },
        LogicalPlan::Project { input, exprs } => PhysicalPlan::Project {
            input: Box::new(lower(input)),
            exprs: exprs.clone(),
        },
        LogicalPlan::Aggregate { input, group, aggs } => PhysicalPlan::Aggregate {
            input: Box::new(lower(input)),
            group: group.clone(),
            aggs: aggs.clone(),
        },
        LogicalPlan::Join { left, right, kind, equi, residual } => PhysicalPlan::Join {
            left: Box::new(lower(left)),
            right: Box::new(lower(right)),
            kind: *kind,
            equi: equi.clone(),
            residual: residual.clone(),
            swap_build: false,
        },
        LogicalPlan::Sort { input, keys } => PhysicalPlan::Sort {
            input: Box::new(lower(input)),
            keys: keys.clone(),
        },
        LogicalPlan::Limit { input, n } => PhysicalPlan::Limit {
            input: Box::new(lower(input)),
            n: *n,
        },
    }
}

/// Lower `plan` and apply the cost-based rewrite pipeline against
/// `catalog`'s statistics. With no catalog only the purely structural
/// rules (constant elimination, projection pushdown) run.
///
/// The returned plan is guaranteed to produce byte-identical results —
/// including the query's Ok/Err status — to `lower(plan)` under every
/// execution shape.
pub fn rewrite_plan(
    plan: &LogicalPlan,
    catalog: Option<&Catalog>,
    _udfs: &UdfRegistry,
) -> (PhysicalPlan, RewriteReport) {
    let mut report = RewriteReport::default();
    let mut p = lower(plan);
    p = const_eliminate(p, &mut report);
    p = push_predicates(p, catalog, &mut report);
    if let Some(cat) = catalog {
        p = embed_scan_filters(p, cat, &mut report);
        p = prune_scans(p, None, cat, &mut report);
        p = choose_join_order(p, cat, &mut report);
    }
    (p, report)
}

/// Apply `f` to every direct child of `p`, rebuilding the node.
fn map_children<F: FnMut(PhysicalPlan) -> PhysicalPlan>(p: PhysicalPlan, f: &mut F) -> PhysicalPlan {
    match p {
        PhysicalPlan::Scan { .. } | PhysicalPlan::TableFunc { .. } => p,
        PhysicalPlan::Filter { input, predicate } => PhysicalPlan::Filter {
            input: Box::new(f(*input)),
            predicate,
        },
        PhysicalPlan::Project { input, exprs } => PhysicalPlan::Project {
            input: Box::new(f(*input)),
            exprs,
        },
        PhysicalPlan::Aggregate { input, group, aggs } => PhysicalPlan::Aggregate {
            input: Box::new(f(*input)),
            group,
            aggs,
        },
        PhysicalPlan::Join { left, right, kind, equi, residual, swap_build } => {
            PhysicalPlan::Join {
                left: Box::new(f(*left)),
                right: Box::new(f(*right)),
                kind,
                equi,
                residual,
                swap_build,
            }
        }
        PhysicalPlan::Sort { input, keys } => PhysicalPlan::Sort {
            input: Box::new(f(*input)),
            keys,
        },
        PhysicalPlan::Limit { input, n } => PhysicalPlan::Limit {
            input: Box::new(f(*input)),
            n,
        },
    }
}

// ------------------------------------------------------- conjunct utils

/// Split a predicate into its top-level AND conjuncts, in written order.
fn split_conjuncts(e: &Expr, out: &mut Vec<Expr>) {
    if let Expr::Binary { op: BinaryOp::And, left, right } = e {
        split_conjuncts(left, out);
        split_conjuncts(right, out);
    } else {
        out.push(e.clone());
    }
}

/// Re-AND a non-empty conjunct list (left-deep, preserving order).
fn rebuild_conjuncts(mut cs: Vec<Expr>) -> Expr {
    let mut e = cs.remove(0);
    for c in cs {
        e = Expr::Binary { op: BinaryOp::And, left: Box::new(e), right: Box::new(c) };
    }
    e
}

// ------------------------------------------------------- constant-elim

/// Evaluate a pure-literal boolean expression at plan time. Returns
/// `Some` only when the expression contains no columns or functions,
/// every sub-expression is well-typed (so the columnar kernels cannot
/// error on it either), and the value is known. Mirrors kernel
/// semantics exactly: numerics compare as f64, AND/OR are Kleene.
fn const_bool_safe(e: &Expr) -> Option<bool> {
    match e {
        Expr::Literal(Value::Bool(b)) => Some(*b),
        Expr::Unary { op: UnaryOp::Not, expr } => const_bool_safe(expr).map(|b| !b),
        Expr::Binary { op: BinaryOp::And, left, right } => {
            match (const_bool_safe(left)?, const_bool_safe(right)?) {
                (true, true) => Some(true),
                _ => Some(false),
            }
        }
        Expr::Binary { op: BinaryOp::Or, left, right } => {
            match (const_bool_safe(left)?, const_bool_safe(right)?) {
                (false, false) => Some(false),
                _ => Some(true),
            }
        }
        Expr::Binary { op, left, right } if is_cmp(*op) => {
            let ord = lit_f64(left)?.partial_cmp(&lit_f64(right)?)?;
            use std::cmp::Ordering::*;
            Some(match op {
                BinaryOp::Eq => ord == Equal,
                BinaryOp::NotEq => ord != Equal,
                BinaryOp::Lt => ord == Less,
                BinaryOp::LtEq => ord != Greater,
                BinaryOp::Gt => ord == Greater,
                BinaryOp::GtEq => ord != Less,
                _ => unreachable!(),
            })
        }
        _ => None,
    }
}

fn is_cmp(op: BinaryOp) -> bool {
    matches!(
        op,
        BinaryOp::Eq | BinaryOp::NotEq | BinaryOp::Lt | BinaryOp::LtEq | BinaryOp::Gt | BinaryOp::GtEq
    )
}

fn lit_f64(e: &Expr) -> Option<f64> {
    match e {
        Expr::Literal(Value::Int(i)) => Some(*i as f64),
        Expr::Literal(Value::Float(f)) => Some(*f),
        _ => None,
    }
}

/// Drop always-true literal conjuncts; remove filters that become empty.
fn const_eliminate(p: PhysicalPlan, report: &mut RewriteReport) -> PhysicalPlan {
    match p {
        PhysicalPlan::Filter { input, predicate } => {
            let input = const_eliminate(*input, report);
            let mut conjuncts = Vec::new();
            split_conjuncts(&predicate, &mut conjuncts);
            let total = conjuncts.len();
            let kept: Vec<Expr> = conjuncts
                .into_iter()
                .filter(|c| const_bool_safe(c) != Some(true))
                .collect();
            if kept.len() == total {
                return PhysicalPlan::Filter { input: Box::new(input), predicate };
            }
            report.fire(
                "constant-elim",
                format!(
                    "dropped {} always-true conjunct(s) of {}",
                    total - kept.len(),
                    predicate.to_sql()
                ),
            );
            if kept.is_empty() {
                input
            } else {
                PhysicalPlan::Filter { input: Box::new(input), predicate: rebuild_conjuncts(kept) }
            }
        }
        other => map_children(other, &mut |c| const_eliminate(c, report)),
    }
}

// --------------------------------------------------- predicate pushdown

/// Push filters below rename-only projections and join inputs.
fn push_predicates(
    p: PhysicalPlan,
    cat: Option<&Catalog>,
    report: &mut RewriteReport,
) -> PhysicalPlan {
    match p {
        PhysicalPlan::Filter { input, predicate } => {
            let input = push_predicates(*input, cat, report);
            match input {
                PhysicalPlan::Project { input: pin, exprs } => {
                    match try_project_pushdown(&predicate, &exprs) {
                        Some(subst) => {
                            report.fire(
                                "predicate-pushdown",
                                format!("{} moved below projection", predicate.to_sql()),
                            );
                            let pushed = push_predicates(
                                PhysicalPlan::Filter { input: pin, predicate: subst },
                                cat,
                                report,
                            );
                            PhysicalPlan::Project { input: Box::new(pushed), exprs }
                        }
                        None => PhysicalPlan::Filter {
                            input: Box::new(PhysicalPlan::Project { input: pin, exprs }),
                            predicate,
                        },
                    }
                }
                j @ PhysicalPlan::Join { .. } => match cat {
                    Some(cat) => try_join_pushdown(j, predicate, cat, report),
                    None => PhysicalPlan::Filter { input: Box::new(j), predicate },
                },
                other => PhysicalPlan::Filter { input: Box::new(other), predicate },
            }
        }
        other => map_children(other, &mut |c| push_predicates(c, cat, report)),
    }
}

/// If the projection only renames columns / broadcasts literals, rewrite
/// `pred` in terms of the projection's *input* and return it.
fn try_project_pushdown(pred: &Expr, exprs: &[(Expr, String)]) -> Option<Expr> {
    if exprs.iter().any(|(e, name)| {
        !matches!(e, Expr::Column(_) | Expr::Literal(_)) || name.starts_with("__")
    }) {
        return None;
    }
    let mut refs = Vec::new();
    pred.referenced_columns(&mut refs);
    let mut map: HashMap<String, Expr> = HashMap::new();
    for name in &refs {
        let hits: Vec<&(Expr, String)> = exprs
            .iter()
            .filter(|(_, out)| out.eq_ignore_ascii_case(name))
            .collect();
        // Exactly one exact (case-insensitive) output-name match keeps
        // the original resolution outcome; anything else declines.
        if hits.len() != 1 {
            return None;
        }
        map.insert(name.to_ascii_lowercase(), hits[0].0.clone());
    }
    Some(substitute(pred, &map))
}

/// Clone `e`, replacing column references found in `map` (keys are
/// lowercase) with their mapped expressions.
fn substitute(e: &Expr, map: &HashMap<String, Expr>) -> Expr {
    match e {
        Expr::Column(name) => map
            .get(&name.to_ascii_lowercase())
            .cloned()
            .unwrap_or_else(|| e.clone()),
        Expr::Literal(_) | Expr::Star => e.clone(),
        Expr::Unary { op, expr } => Expr::Unary {
            op: *op,
            expr: Box::new(substitute(expr, map)),
        },
        Expr::Binary { op, left, right } => Expr::Binary {
            op: *op,
            left: Box::new(substitute(left, map)),
            right: Box::new(substitute(right, map)),
        },
        Expr::Func { name, args } => Expr::Func {
            name: name.clone(),
            args: args.iter().map(|a| substitute(a, map)).collect(),
        },
        Expr::IsNull { expr, negated } => Expr::IsNull {
            expr: Box::new(substitute(expr, map)),
            negated: *negated,
        },
        Expr::InList { expr, list, negated } => Expr::InList {
            expr: Box::new(substitute(expr, map)),
            list: list.iter().map(|x| substitute(x, map)).collect(),
            negated: *negated,
        },
        Expr::Between { expr, low, high, negated } => Expr::Between {
            expr: Box::new(substitute(expr, map)),
            low: Box::new(substitute(low, map)),
            high: Box::new(substitute(high, map)),
            negated: *negated,
        },
        Expr::Case { branches, else_value } => Expr::Case {
            branches: branches
                .iter()
                .map(|(c, v)| (substitute(c, map), substitute(v, map)))
                .collect(),
            else_value: else_value
                .as_ref()
                .map(|e| Box::new(substitute(e, map))),
        },
    }
}

// ----------------------------------------------- join predicate pushdown

/// Which join input a conjunct's columns all land on.
#[derive(PartialEq, Clone, Copy)]
enum Side {
    Left,
    Right,
}

/// Try to move single-side conjuncts of `predicate` below `join`.
/// Declines (returning the unmodified filter-over-join) unless every
/// moved *and* every remaining expression is proven total, so the
/// rewrite cannot change the query's error behavior.
fn try_join_pushdown(
    join: PhysicalPlan,
    predicate: Expr,
    cat: &Catalog,
    report: &mut RewriteReport,
) -> PhysicalPlan {
    let keep = |join: PhysicalPlan, predicate: Expr| PhysicalPlan::Filter {
        input: Box::new(join),
        predicate,
    };
    let PhysicalPlan::Join { left, right, kind, equi, residual, swap_build } = join else {
        unreachable!("try_join_pushdown called on non-join");
    };
    let repack = |left: Box<PhysicalPlan>, right: Box<PhysicalPlan>| PhysicalPlan::Join {
        left,
        right,
        kind,
        equi: equi.clone(),
        residual: residual.clone(),
        swap_build,
    };

    // Both sides must bottom out at a scan through filters only, so the
    // runtime side schemas are statically known.
    let (Some((ltable, lschema)), Some((rtable, rschema))) =
        (scan_schema(&left, cat), scan_schema(&right, cat))
    else {
        return keep(repack(left, right), predicate);
    };
    let lstats = cat.stats().table(&ltable);
    let rstats = cat.stats().table(&rtable);

    // Equi-key expressions re-evaluate over post-push (smaller) side
    // inputs; bare columns/literals are the only shapes whose errors are
    // provably row-independent.
    if !equi
        .iter()
        .all(|(a, b)| matches!(a, Expr::Column(_) | Expr::Literal(_)) && matches!(b, Expr::Column(_) | Expr::Literal(_)))
    {
        return keep(repack(left, right), predicate);
    }

    // Static mirror of the executor's combined join schema.
    let lalias = phys_alias(&left, "l");
    let ralias = phys_alias(&right, "r");
    let combined = combined_schema(&lschema, &lalias, &rschema, &ralias);
    let llen = lschema.fields.len();
    let nan_free_combined = |idx: usize| {
        if idx < llen {
            nan_free(lstats.as_ref(), &lschema.fields[idx].name)
        } else {
            nan_free(rstats.as_ref(), &rschema.fields[idx - llen].name)
        }
    };

    let mut conjuncts = Vec::new();
    split_conjuncts(&predicate, &mut conjuncts);
    let mut lpush = Vec::new();
    let mut rpush = Vec::new();
    let mut remaining = Vec::new();
    for c in conjuncts {
        match conjunct_side(&c, &combined, llen, &lschema, &rschema) {
            Some(Side::Left)
                if proven(&c, &lschema, &|i| nan_free(lstats.as_ref(), &lschema.fields[i].name))
                    .map(|(dt, _)| dt)
                    == Some(DataType::Bool) =>
            {
                lpush.push(c)
            }
            Some(Side::Right)
                if kind == JoinKind::Inner
                    && proven(&c, &rschema, &|i| {
                        nan_free(rstats.as_ref(), &rschema.fields[i].name)
                    })
                    .map(|(dt, _)| dt)
                        == Some(DataType::Bool) =>
            {
                rpush.push(c)
            }
            _ => remaining.push(c),
        }
    }
    if lpush.is_empty() && rpush.is_empty() {
        return keep(repack(left, right), predicate);
    }
    // Remaining conjuncts and the residual now see fewer rows — they too
    // must be proven total over the combined schema, else decline all.
    let safe_above = |e: &Expr| {
        proven(e, &combined, &nan_free_combined).map(|(dt, _)| dt) == Some(DataType::Bool)
    };
    if !remaining.iter().all(safe_above)
        || !residual.as_ref().map_or(true, safe_above)
    {
        return keep(repack(left, right), predicate);
    }

    for c in &lpush {
        report.fire("join-pushdown", format!("{} → left side ({ltable})", c.to_sql()));
    }
    for c in &rpush {
        report.fire("join-pushdown", format!("{} → right side ({rtable})", c.to_sql()));
    }
    let wrap = |side: Box<PhysicalPlan>, push: Vec<Expr>| {
        if push.is_empty() {
            side
        } else {
            Box::new(PhysicalPlan::Filter { input: side, predicate: rebuild_conjuncts(push) })
        }
    };
    let new_join = repack(wrap(left, lpush), wrap(right, rpush));
    if remaining.is_empty() {
        new_join
    } else {
        PhysicalPlan::Filter { input: Box::new(new_join), predicate: rebuild_conjuncts(remaining) }
    }
}

/// Table name + schema of a side that is a scan under zero or more
/// filters (schema flows through filters unchanged).
fn scan_schema(p: &PhysicalPlan, cat: &Catalog) -> Option<(String, Schema)> {
    match p {
        PhysicalPlan::Scan { table, .. } => {
            let (schema, _) = cat.schema_of(table)?;
            Some((table.clone(), schema))
        }
        PhysicalPlan::Filter { input, .. } => scan_schema(input, cat),
        _ => None,
    }
}

/// Mirror of the executor's `plan_alias` over physical plans.
fn phys_alias(p: &PhysicalPlan, default: &str) -> String {
    match p {
        PhysicalPlan::Scan { table, alias, .. } => {
            alias.clone().unwrap_or_else(|| table.clone())
        }
        PhysicalPlan::TableFunc { name, alias, .. } => {
            alias.clone().unwrap_or_else(|| name.clone())
        }
        PhysicalPlan::Filter { input, .. }
        | PhysicalPlan::Limit { input, .. }
        | PhysicalPlan::Sort { input, .. } => phys_alias(input, default),
        _ => default.to_string(),
    }
}

/// Static mirror of the executor's `join_schema`: colliding names are
/// qualified `alias.name`, all fields kept left-then-right.
fn combined_schema(l: &Schema, lalias: &str, r: &Schema, ralias: &str) -> Schema {
    let collides =
        |name: &str| l.index_of(name).is_some() && r.index_of(name).is_some();
    let mut fields = Vec::new();
    for f in &l.fields {
        let name = if collides(&f.name) {
            format!("{lalias}.{}", f.name)
        } else {
            f.name.clone()
        };
        fields.push(Field::new(name, f.data_type));
    }
    for f in &r.fields {
        let name = if collides(&f.name) {
            format!("{ralias}.{}", f.name)
        } else {
            f.name.clone()
        };
        fields.push(Field::new(name, f.data_type));
    }
    Schema::new(fields)
}

/// Classify which side every column of `c` lands on, requiring that each
/// name resolves in the combined schema *and* resolves to the very same
/// physical column in the side schema. `None` ⇒ mixed/unresolvable.
fn conjunct_side(
    c: &Expr,
    combined: &Schema,
    llen: usize,
    lschema: &Schema,
    rschema: &Schema,
) -> Option<Side> {
    let mut refs = Vec::new();
    c.referenced_columns(&mut refs);
    if refs.is_empty() {
        return None;
    }
    let mut side: Option<Side> = None;
    for name in &refs {
        let ci = resolve_column(combined, name).ok()?;
        let (this, schema, si_expect) = if ci < llen {
            (Side::Left, lschema, ci)
        } else {
            (Side::Right, rschema, ci - llen)
        };
        if resolve_column(schema, name).ok()? != si_expect {
            return None;
        }
        match side {
            None => side = Some(this),
            Some(s) if s == this => {}
            _ => return None,
        }
    }
    side
}

/// Is the named column provably NaN-free? Integer columns always are;
/// float columns qualify when every non-NULL value landed in the
/// histogram (i.e. was finite) at registration.
fn nan_free(stats: Option<&TableStats>, col: &str) -> bool {
    let Some(ts) = stats else { return false };
    let Some(cs) = ts.column(col) else { return false };
    match &cs.histogram {
        Some(h) => ts.rows.saturating_sub(cs.null_count) == h.count(),
        // No histogram ⇒ no finite numeric values; a column that is all
        // NULL/strings/bools never reaches a numeric comparison anyway,
        // but stay conservative.
        None => false,
    }
}

/// Prove an expression *total* over `schema`: evaluation can never
/// return an error, for any row values. Returns the proven output type
/// and whether the value is NaN-safe (relevant because comparing NaN is
/// a runtime error in this engine). `None` ⇒ no proof; caller declines.
fn proven(
    e: &Expr,
    schema: &Schema,
    nan_free_col: &dyn Fn(usize) -> bool,
) -> Option<(DataType, bool)> {
    let numeric = |dt: DataType| matches!(dt, DataType::Int64 | DataType::Float64);
    match e {
        Expr::Literal(Value::Int(_)) => Some((DataType::Int64, true)),
        Expr::Literal(Value::Float(f)) => Some((DataType::Float64, f.is_finite())),
        Expr::Literal(Value::Str(_)) => Some((DataType::Utf8, true)),
        Expr::Literal(Value::Bool(_)) => Some((DataType::Bool, true)),
        Expr::Literal(Value::Null) => None,
        Expr::Column(name) => {
            let i = resolve_column(schema, name).ok()?;
            let dt = schema.fields[i].data_type;
            Some((dt, dt != DataType::Float64 || nan_free_col(i)))
        }
        Expr::Unary { op: UnaryOp::Neg, expr } => {
            let (dt, ns) = proven(expr, schema, nan_free_col)?;
            numeric(dt).then_some((dt, ns))
        }
        Expr::Unary { op: UnaryOp::Not, expr } => {
            let (dt, _) = proven(expr, schema, nan_free_col)?;
            (dt == DataType::Bool).then_some((DataType::Bool, true))
        }
        Expr::Binary { op, left, right } => {
            let (ldt, lns) = proven(left, schema, nan_free_col)?;
            let (rdt, rns) = proven(right, schema, nan_free_col)?;
            match op {
                BinaryOp::Add | BinaryOp::Sub | BinaryOp::Mul | BinaryOp::Div | BinaryOp::Mod => {
                    if !(numeric(ldt) && numeric(rdt)) {
                        return None;
                    }
                    let dt = if matches!(op, BinaryOp::Div)
                        || ldt == DataType::Float64
                        || rdt == DataType::Float64
                    {
                        DataType::Float64
                    } else {
                        DataType::Int64
                    };
                    // Float arithmetic can overflow to ±∞ and combine
                    // into NaN; only all-integer results stay NaN-safe.
                    Some((dt, dt == DataType::Int64))
                }
                BinaryOp::Eq
                | BinaryOp::NotEq
                | BinaryOp::Lt
                | BinaryOp::LtEq
                | BinaryOp::Gt
                | BinaryOp::GtEq => {
                    comparable(ldt, lns, rdt, rns).then_some((DataType::Bool, true))
                }
                BinaryOp::And | BinaryOp::Or => (ldt == DataType::Bool
                    && rdt == DataType::Bool)
                    .then_some((DataType::Bool, true)),
                BinaryOp::Concat => None,
            }
        }
        Expr::IsNull { expr, .. } => {
            proven(expr, schema, nan_free_col).map(|_| (DataType::Bool, true))
        }
        Expr::Between { expr, low, high, .. } => {
            let (vdt, vns) = proven(expr, schema, nan_free_col)?;
            let (ldt, lns) = proven(low, schema, nan_free_col)?;
            let (hdt, hns) = proven(high, schema, nan_free_col)?;
            (comparable(vdt, vns, ldt, lns) && comparable(vdt, vns, hdt, hns))
                .then_some((DataType::Bool, true))
        }
        Expr::InList { expr, list, .. } => {
            let (edt, ens) = proven(expr, schema, nan_free_col)?;
            list.iter()
                .try_fold((), |(), item| {
                    let (idt, ins) = proven(item, schema, nan_free_col)?;
                    comparable(edt, ens, idt, ins).then_some(())
                })
                .map(|()| (DataType::Bool, true))
        }
        Expr::Func { .. } | Expr::Case { .. } | Expr::Star => None,
    }
}

/// Can two proven operand types always be compared without error?
/// Numerics need NaN-safety on both sides (NaN comparisons error).
fn comparable(ldt: DataType, lns: bool, rdt: DataType, rns: bool) -> bool {
    let numeric = |dt: DataType| matches!(dt, DataType::Int64 | DataType::Float64);
    match (ldt, rdt) {
        (DataType::Utf8, DataType::Utf8) | (DataType::Bool, DataType::Bool) => true,
        _ => numeric(ldt) && numeric(rdt) && lns && rns,
    }
}

// ------------------------------------------------------------ scan-embed

/// Embed a selective filter directly above a large scan into the scan
/// itself, so shipping decisions see post-filter cardinality. The
/// predicate is evaluated over exactly the same rows either way, so no
/// totality proof is needed.
fn embed_scan_filters(p: PhysicalPlan, cat: &Catalog, report: &mut RewriteReport) -> PhysicalPlan {
    match p {
        PhysicalPlan::Filter { input, predicate } => {
            let input = embed_scan_filters(*input, cat, report);
            if let PhysicalPlan::Scan { table, alias, predicate: None, live } = input {
                let rows = cat.stats().table_rows(&table).unwrap_or(0);
                let sel = cat.stats().estimate_selectivity(&table, &predicate);
                if rows as usize >= MORSEL_MIN_ROWS && sel <= EMBED_MAX_SELECTIVITY {
                    report.fire(
                        "scan-embed",
                        format!(
                            "scan {table}: embedded {} (est sel {sel:.3})",
                            predicate.to_sql()
                        ),
                    );
                    return PhysicalPlan::Scan { table, alias, predicate: Some(predicate), live };
                }
                return PhysicalPlan::Filter {
                    input: Box::new(PhysicalPlan::Scan { table, alias, predicate: None, live }),
                    predicate,
                };
            }
            PhysicalPlan::Filter { input: Box::new(input), predicate }
        }
        other => map_children(other, &mut |c| embed_scan_filters(c, cat, report)),
    }
}

// ------------------------------------------------------ projection-prune

/// Top-down live-column analysis: `needed` is the set of column names
/// the operators above can observe, or `None` for "everything".
fn prune_scans(
    p: PhysicalPlan,
    needed: Option<&BTreeSet<String>>,
    cat: &Catalog,
    report: &mut RewriteReport,
) -> PhysicalPlan {
    match p {
        PhysicalPlan::Scan { table, alias, predicate, live } => {
            let Some(names) = needed else {
                return PhysicalPlan::Scan { table, alias, predicate, live };
            };
            let mut names = names.clone();
            if let Some(pr) = &predicate {
                add_refs(&mut names, pr);
            }
            let Some((schema, _)) = cat.schema_of(&table) else {
                return PhysicalPlan::Scan { table, alias, predicate, live };
            };
            let mut keep: BTreeSet<usize> = BTreeSet::new();
            for name in &names {
                let cands = candidate_indices(&schema, name);
                if cands.is_empty() {
                    // Unknown column: decline so the runtime error (which
                    // lists the schema's names) is reproduced verbatim.
                    return PhysicalPlan::Scan { table, alias, predicate, live };
                }
                keep.extend(cands);
            }
            if keep.is_empty() {
                keep.insert(0); // keep one column so the row count survives
            }
            if keep.len() == schema.fields.len() {
                return PhysicalPlan::Scan { table, alias, predicate, live };
            }
            report.fire(
                "projection-prune",
                format!("scan {table}: {}/{} columns live", keep.len(), schema.fields.len()),
            );
            PhysicalPlan::Scan {
                table,
                alias,
                predicate,
                live: Some(keep.into_iter().collect()),
            }
        }
        PhysicalPlan::TableFunc { .. } => p,
        PhysicalPlan::Filter { input, predicate } => {
            let child = needed.map(|n| {
                let mut n = n.clone();
                add_refs(&mut n, &predicate);
                n
            });
            PhysicalPlan::Filter {
                input: Box::new(prune_scans(*input, child.as_ref(), cat, report)),
                predicate,
            }
        }
        PhysicalPlan::Sort { input, keys } => {
            let child = needed.map(|n| {
                let mut n = n.clone();
                for k in &keys {
                    add_refs(&mut n, &k.expr);
                }
                n
            });
            PhysicalPlan::Sort {
                input: Box::new(prune_scans(*input, child.as_ref(), cat, report)),
                keys,
            }
        }
        PhysicalPlan::Limit { input, n } => PhysicalPlan::Limit {
            input: Box::new(prune_scans(*input, needed, cat, report)),
            n,
        },
        PhysicalPlan::Project { input, exprs } => {
            let child = project_needs(&exprs);
            PhysicalPlan::Project {
                input: Box::new(prune_scans(*input, child.as_ref(), cat, report)),
                exprs,
            }
        }
        PhysicalPlan::Aggregate { input, group, aggs } => {
            let mut n = BTreeSet::new();
            let mut star = false;
            for (e, _) in &group {
                star |= contains_star(e);
                add_refs(&mut n, e);
            }
            for a in &aggs {
                for e in &a.args {
                    star |= contains_star(e);
                    add_refs(&mut n, e);
                }
            }
            let child = if star { None } else { Some(n) };
            PhysicalPlan::Aggregate {
                input: Box::new(prune_scans(*input, child.as_ref(), cat, report)),
                group,
                aggs,
            }
        }
        PhysicalPlan::Join { left, right, kind, equi, residual, swap_build } => {
            // Join sides feed the combined schema (collision detection,
            // residual resolution); keep them whole.
            PhysicalPlan::Join {
                left: Box::new(prune_scans(*left, None, cat, report)),
                right: Box::new(prune_scans(*right, None, cat, report)),
                kind,
                equi,
                residual,
                swap_build,
            }
        }
    }
}

/// The columns a projection needs from its input, or `None` when the
/// projection passes through unknown columns (`*` / hidden markers).
fn project_needs(exprs: &[(Expr, String)]) -> Option<BTreeSet<String>> {
    let mut n = BTreeSet::new();
    for (e, name) in exprs {
        if name.starts_with("__") || contains_star(e) {
            return None;
        }
        add_refs(&mut n, e);
    }
    Some(n)
}

fn add_refs(set: &mut BTreeSet<String>, e: &Expr) {
    let mut refs = Vec::new();
    e.referenced_columns(&mut refs);
    set.extend(refs);
}

fn contains_star(e: &Expr) -> bool {
    match e {
        Expr::Star => true,
        Expr::Literal(_) | Expr::Column(_) => false,
        Expr::Unary { expr, .. } => contains_star(expr),
        Expr::Binary { left, right, .. } => contains_star(left) || contains_star(right),
        Expr::Func { args, .. } => args.iter().any(contains_star),
        Expr::IsNull { expr, .. } => contains_star(expr),
        Expr::InList { expr, list, .. } => {
            contains_star(expr) || list.iter().any(contains_star)
        }
        Expr::Between { expr, low, high, .. } => {
            contains_star(expr) || contains_star(low) || contains_star(high)
        }
        Expr::Case { branches, else_value } => {
            branches.iter().any(|(c, v)| contains_star(c) || contains_star(v))
                || else_value.as_deref().map_or(false, contains_star)
        }
    }
}

/// Every schema index the given (possibly qualified) name could resolve
/// to under any of `resolve_column`'s tiers. Keeping the whole candidate
/// set preserves both the resolution outcome and ambiguity errors.
fn candidate_indices(schema: &Schema, name: &str) -> Vec<usize> {
    let mut out: BTreeSet<usize> = BTreeSet::new();
    for (i, f) in schema.fields.iter().enumerate() {
        if f.name.eq_ignore_ascii_case(name) {
            out.insert(i);
        }
    }
    if let Some((_, bare)) = name.split_once('.') {
        for (i, f) in schema.fields.iter().enumerate() {
            if f.name.eq_ignore_ascii_case(bare) {
                out.insert(i);
            }
        }
    } else {
        for (i, f) in schema.fields.iter().enumerate() {
            if f.name
                .rsplit_once('.')
                .map_or(false, |(_, suffix)| suffix.eq_ignore_ascii_case(name))
            {
                out.insert(i);
            }
        }
    }
    out.into_iter().collect()
}

// --------------------------------------------------------- join ordering

/// Pick the smaller estimated side as the hash-join build side.
fn choose_join_order(p: PhysicalPlan, cat: &Catalog, report: &mut RewriteReport) -> PhysicalPlan {
    match p {
        PhysicalPlan::Join { left, right, kind, equi, residual, swap_build } => {
            let left = Box::new(choose_join_order(*left, cat, report));
            let right = Box::new(choose_join_order(*right, cat, report));
            let mut swap = swap_build;
            if kind == JoinKind::Inner && !equi.is_empty() {
                if let (Some(le), Some(re)) = (est_rows(&left, cat), est_rows(&right, cat)) {
                    if re > le {
                        swap = true;
                        report.fire(
                            "join-swap",
                            format!(
                                "build on left (~{} rows) instead of right (~{} rows)",
                                le.round() as u64,
                                re.round() as u64
                            ),
                        );
                    }
                }
            }
            PhysicalPlan::Join { left, right, kind, equi, residual, swap_build: swap }
        }
        other => map_children(other, &mut |c| choose_join_order(c, cat, report)),
    }
}

/// Nearest scan's table name below filter chains.
fn scan_table_below(p: &PhysicalPlan) -> Option<&str> {
    match p {
        PhysicalPlan::Scan { table, .. } => Some(table),
        PhysicalPlan::Filter { input, .. } => scan_table_below(input),
        _ => None,
    }
}

/// Estimated output cardinality from table statistics; `None` when the
/// plan reads something the stats store has never seen.
fn est_rows(p: &PhysicalPlan, cat: &Catalog) -> Option<f64> {
    match p {
        PhysicalPlan::Scan { table, predicate, .. } => {
            let rows = cat.stats().table_rows(table)? as f64;
            Some(match predicate {
                Some(pr) => rows * cat.stats().estimate_selectivity(table, pr),
                None => rows,
            })
        }
        PhysicalPlan::TableFunc { .. } => None,
        PhysicalPlan::Filter { input, predicate } => {
            let r = est_rows(input, cat)?;
            let sel = match scan_table_below(input) {
                Some(t) => cat.stats().estimate_selectivity(t, predicate),
                None => DEFAULT_SELECTIVITY,
            };
            Some(r * sel)
        }
        PhysicalPlan::Project { input, .. } | PhysicalPlan::Sort { input, .. } => {
            est_rows(input, cat)
        }
        PhysicalPlan::Limit { input, n } => Some(match est_rows(input, cat) {
            Some(r) => r.min(*n as f64),
            None => *n as f64,
        }),
        PhysicalPlan::Aggregate { input, group, .. } => {
            let r = est_rows(input, cat)?;
            Some(if group.is_empty() { 1.0 } else { r.sqrt().ceil() })
        }
        PhysicalPlan::Join { left, right, equi, .. } => {
            let l = est_rows(left, cat)?;
            let r = est_rows(right, cat)?;
            Some(if equi.is_empty() { l * r } else { l.max(r) })
        }
    }
}

// --------------------------------------------------------------- explain

/// Render the optimized plan for `plan` with per-node estimated
/// rows/bytes plus the rules that fired — the one stable text format
/// shared by `run-sql --explain`, `check-sql`, and the golden tests.
/// The output depends only on the plan and catalog statistics, never on
/// the execution shape.
pub fn explain_plan(plan: &LogicalPlan, catalog: Option<&Catalog>, udfs: &UdfRegistry) -> String {
    let (phys, report) = rewrite_plan(plan, catalog, udfs);
    let mut out = String::new();
    render_node(&phys, catalog, 0, &mut out);
    out.push_str("rules fired:\n");
    if report.fired.is_empty() {
        out.push_str("  (none)\n");
    } else {
        for f in &report.fired {
            out.push_str("  - ");
            out.push_str(f.rule);
            out.push_str(": ");
            out.push_str(&f.detail);
            out.push('\n');
        }
    }
    out
}

fn render_node(p: &PhysicalPlan, cat: Option<&Catalog>, depth: usize, out: &mut String) {
    for _ in 0..depth {
        out.push_str("  ");
    }
    out.push_str(&node_label(p, cat));
    let rows = cat.and_then(|c| est_rows(p, c));
    match rows {
        Some(r) => {
            out.push_str(&format!("  ~{} rows", r.round() as u64));
            if let Some(cols) = out_cols(p, cat) {
                out.push_str(&format!(", ~{} B", (r.round() as u64) * cols as u64 * 8));
            }
        }
        None => out.push_str("  ~? rows"),
    }
    out.push('\n');
    match p {
        PhysicalPlan::Scan { .. } | PhysicalPlan::TableFunc { .. } => {}
        PhysicalPlan::Filter { input, .. }
        | PhysicalPlan::Project { input, .. }
        | PhysicalPlan::Aggregate { input, .. }
        | PhysicalPlan::Sort { input, .. }
        | PhysicalPlan::Limit { input, .. } => render_node(input, cat, depth + 1, out),
        PhysicalPlan::Join { left, right, .. } => {
            render_node(left, cat, depth + 1, out);
            render_node(right, cat, depth + 1, out);
        }
    }
}

fn node_label(p: &PhysicalPlan, cat: Option<&Catalog>) -> String {
    match p {
        PhysicalPlan::Scan { table, alias, predicate, live } => {
            let mut s = format!("scan {table}");
            if let Some(a) = alias {
                s.push_str(&format!(" as {a}"));
            }
            if let Some(pr) = predicate {
                s.push_str(&format!(" where {}", pr.to_sql()));
            }
            if let Some(l) = live {
                match cat.and_then(|c| c.schema_of(table)) {
                    Some((schema, _)) => {
                        s.push_str(&format!(" [cols {}/{}]", l.len(), schema.fields.len()))
                    }
                    None => s.push_str(&format!(" [cols {}]", l.len())),
                }
            }
            s
        }
        PhysicalPlan::TableFunc { name, .. } => format!("table-func {name}"),
        PhysicalPlan::Filter { predicate, .. } => {
            format!("filter {}", predicate.to_sql())
        }
        PhysicalPlan::Project { exprs, .. } => {
            let names: Vec<&str> = exprs.iter().map(|(_, n)| n.as_str()).collect();
            format!("project [{}]", names.join(", "))
        }
        PhysicalPlan::Aggregate { group, aggs, .. } => {
            let g: Vec<&str> = group.iter().map(|(_, n)| n.as_str()).collect();
            let a: Vec<&str> = aggs.iter().map(|c| c.out_name.as_str()).collect();
            format!("aggregate group=[{}] aggs=[{}]", g.join(", "), a.join(", "))
        }
        PhysicalPlan::Join { kind, equi, residual, swap_build, .. } => {
            let mut s = format!(
                "join {}",
                match kind {
                    JoinKind::Inner => "inner",
                    JoinKind::Left => "left",
                }
            );
            if !equi.is_empty() {
                let keys: Vec<String> = equi
                    .iter()
                    .map(|(a, b)| format!("{} = {}", a.to_sql(), b.to_sql()))
                    .collect();
                s.push_str(&format!(" on {}", keys.join(", ")));
            }
            if let Some(r) = residual {
                s.push_str(&format!(" filter {}", r.to_sql()));
            }
            if *swap_build {
                s.push_str(" [build=left]");
            }
            s
        }
        PhysicalPlan::Sort { keys, .. } => {
            let ks: Vec<String> = keys
                .iter()
                .map(|k| {
                    format!("{}{}", k.expr.to_sql(), if k.descending { " desc" } else { "" })
                })
                .collect();
            format!("sort [{}]", ks.join(", "))
        }
        PhysicalPlan::Limit { n, .. } => format!("limit {n}"),
    }
}

/// Output column count, when statically known.
fn out_cols(p: &PhysicalPlan, cat: Option<&Catalog>) -> Option<usize> {
    match p {
        PhysicalPlan::Scan { table, live, .. } => match live {
            Some(l) => Some(l.len()),
            None => Some(cat?.schema_of(table)?.0.fields.len()),
        },
        PhysicalPlan::TableFunc { .. } => None,
        PhysicalPlan::Filter { input, .. }
        | PhysicalPlan::Sort { input, .. }
        | PhysicalPlan::Limit { input, .. } => out_cols(input, cat),
        PhysicalPlan::Project { exprs, .. } => {
            if exprs
                .iter()
                .any(|(e, n)| n.starts_with("__") || contains_star(e))
            {
                None
            } else {
                Some(exprs.len())
            }
        }
        PhysicalPlan::Aggregate { group, aggs, .. } => Some(group.len() + aggs.len()),
        PhysicalPlan::Join { left, right, .. } => {
            Some(out_cols(left, cat)? + out_cols(right, cat)?)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sql::parse_query;
    use crate::types::{Column, RowSet};

    fn plan(sql: &str) -> LogicalPlan {
        super::super::plan::plan_query(&parse_query(sql).unwrap(), &UdfRegistry::new()).unwrap()
    }

    fn table(n: usize) -> RowSet {
        let k: Vec<i64> = (0..n as i64).collect();
        let v: Vec<f64> = (0..n).map(|i| i as f64 % 100.0).collect();
        let name: Vec<String> = (0..n).map(|i| format!("n{}", i % 10)).collect();
        RowSet::new(
            Schema::new(vec![
                Field::new("k", DataType::Int64),
                Field::new("v", DataType::Float64),
                Field::new("name", DataType::Utf8),
            ]),
            vec![
                Column::from_i64(k),
                Column::from_f64(v),
                Column::from_strings(name),
            ],
        )
        .unwrap()
    }

    fn catalog() -> Catalog {
        let cat = Catalog::new();
        cat.register("t", table(8192));
        cat.register("small", table(64));
        cat.register("big", table(8192));
        cat
    }

    fn fired(report: &RewriteReport, rule: &str) -> bool {
        report.fired.iter().any(|f| f.rule == rule)
    }

    #[test]
    fn lower_is_purely_structural() {
        let p = lower(&plan("SELECT v FROM t WHERE v < 1.0"));
        let PhysicalPlan::Project { input, .. } = p else { panic!("want project") };
        let PhysicalPlan::Filter { input, .. } = *input else { panic!("want filter") };
        let PhysicalPlan::Scan { predicate, live, .. } = *input else { panic!("want scan") };
        assert!(predicate.is_none());
        assert!(live.is_none());
    }

    #[test]
    fn constant_elim_drops_true_conjuncts() {
        let udfs = UdfRegistry::new();
        let (p, report) = rewrite_plan(&plan("SELECT v FROM t WHERE 1 < 2 AND v < 5.0"), None, &udfs);
        assert!(fired(&report, "constant-elim"), "{report:?}");
        let PhysicalPlan::Project { input, .. } = p else { panic!() };
        let PhysicalPlan::Filter { predicate, .. } = *input else { panic!("filter kept") };
        assert_eq!(predicate.to_sql(), "(v < 5.0)");

        let (p, report) = rewrite_plan(&plan("SELECT v FROM t WHERE 2 > 1"), None, &udfs);
        assert!(fired(&report, "constant-elim"));
        let PhysicalPlan::Project { input, .. } = p else { panic!() };
        assert!(matches!(*input, PhysicalPlan::Scan { .. }), "filter removed entirely");
    }

    #[test]
    fn constant_elim_keeps_false_and_column_conjuncts() {
        let udfs = UdfRegistry::new();
        let (p, report) = rewrite_plan(&plan("SELECT v FROM t WHERE 1 > 2"), None, &udfs);
        assert!(!fired(&report, "constant-elim"));
        let PhysicalPlan::Project { input, .. } = p else { panic!() };
        assert!(matches!(*input, PhysicalPlan::Filter { .. }));
    }

    #[test]
    fn scan_embed_fires_only_when_selective_and_large() {
        let cat = catalog();
        let udfs = UdfRegistry::new();
        let (p, report) = rewrite_plan(&plan("SELECT v FROM t WHERE v < 2.0"), Some(&cat), &udfs);
        assert!(fired(&report, "scan-embed"), "{report:?}");
        let PhysicalPlan::Project { input, .. } = p else { panic!() };
        let PhysicalPlan::Scan { predicate, .. } = *input else { panic!("expected embedded scan") };
        assert_eq!(predicate.unwrap().to_sql(), "(v < 2.0)");

        // Not selective enough: filter stays a separate operator.
        let (_, report) = rewrite_plan(&plan("SELECT v FROM t WHERE v < 50.0"), Some(&cat), &udfs);
        assert!(!fired(&report, "scan-embed"));

        // Table too small for shipping to matter.
        let (_, report) =
            rewrite_plan(&plan("SELECT v FROM small WHERE v < 2.0"), Some(&cat), &udfs);
        assert!(!fired(&report, "scan-embed"));
    }

    #[test]
    fn projection_prune_keeps_only_live_columns() {
        let cat = catalog();
        let udfs = UdfRegistry::new();
        let (p, report) =
            rewrite_plan(&plan("SELECT k FROM t WHERE v < 50.0"), Some(&cat), &udfs);
        assert!(fired(&report, "projection-prune"), "{report:?}");
        fn find_scan(p: &PhysicalPlan) -> &PhysicalPlan {
            match p {
                PhysicalPlan::Scan { .. } => p,
                PhysicalPlan::Filter { input, .. }
                | PhysicalPlan::Project { input, .. }
                | PhysicalPlan::Sort { input, .. }
                | PhysicalPlan::Limit { input, .. }
                | PhysicalPlan::Aggregate { input, .. } => find_scan(input),
                PhysicalPlan::Join { left, .. } => find_scan(left),
                PhysicalPlan::TableFunc { .. } => panic!("no scan"),
            }
        }
        let PhysicalPlan::Scan { live, .. } = find_scan(&p) else { panic!() };
        assert_eq!(live.as_deref(), Some(&[0usize, 1][..]), "k + v live, name pruned");

        // SELECT * keeps everything.
        let (p, report) = rewrite_plan(&plan("SELECT * FROM t"), Some(&cat), &udfs);
        assert!(!fired(&report, "projection-prune"));
        let PhysicalPlan::Scan { live, .. } = find_scan(&p) else { panic!() };
        assert!(live.is_none());
    }

    #[test]
    fn predicate_pushdown_through_rename_projection() {
        let udfs = UdfRegistry::new();
        let logical = LogicalPlan::Filter {
            input: Box::new(LogicalPlan::Project {
                input: Box::new(LogicalPlan::Scan { table: "t".into(), alias: None }),
                exprs: vec![(Expr::col("v"), "x".into())],
            }),
            predicate: Expr::Binary {
                op: BinaryOp::Lt,
                left: Box::new(Expr::col("x")),
                right: Box::new(Expr::lit(Value::Float(1.0))),
            },
        };
        let (p, report) = rewrite_plan(&logical, None, &udfs);
        assert!(fired(&report, "predicate-pushdown"), "{report:?}");
        let PhysicalPlan::Project { input, .. } = p else { panic!("project hoisted to root") };
        let PhysicalPlan::Filter { predicate, input } = *input else { panic!("filter below") };
        assert_eq!(predicate.to_sql(), "(v < 1.0)");
        assert!(matches!(*input, PhysicalPlan::Scan { .. }));
    }

    #[test]
    fn computed_projection_declines_pushdown() {
        let udfs = UdfRegistry::new();
        let logical = LogicalPlan::Filter {
            input: Box::new(LogicalPlan::Project {
                input: Box::new(LogicalPlan::Scan { table: "t".into(), alias: None }),
                exprs: vec![(
                    Expr::Binary {
                        op: BinaryOp::Add,
                        left: Box::new(Expr::col("v")),
                        right: Box::new(Expr::lit(Value::Int(1))),
                    },
                    "x".into(),
                )],
            }),
            predicate: Expr::Binary {
                op: BinaryOp::Lt,
                left: Box::new(Expr::col("x")),
                right: Box::new(Expr::lit(Value::Float(1.0))),
            },
        };
        let (p, report) = rewrite_plan(&logical, None, &udfs);
        assert!(!fired(&report, "predicate-pushdown"));
        assert!(matches!(p, PhysicalPlan::Filter { .. }));
    }

    #[test]
    fn join_pushdown_and_swap() {
        let cat = catalog();
        let udfs = UdfRegistry::new();
        let (p, report) = rewrite_plan(
            &plan(
                "SELECT small.k, big.v FROM small JOIN big ON small.k = big.k \
                 WHERE small.v < 10.0",
            ),
            Some(&cat),
            &udfs,
        );
        assert!(fired(&report, "join-pushdown"), "{report:?}");
        assert!(fired(&report, "join-swap"), "{report:?}");
        fn find_join(p: &PhysicalPlan) -> &PhysicalPlan {
            match p {
                PhysicalPlan::Join { .. } => p,
                PhysicalPlan::Filter { input, .. }
                | PhysicalPlan::Project { input, .. }
                | PhysicalPlan::Sort { input, .. }
                | PhysicalPlan::Limit { input, .. }
                | PhysicalPlan::Aggregate { input, .. } => find_join(input),
                _ => panic!("no join in plan"),
            }
        }
        let PhysicalPlan::Join { left, swap_build, .. } = find_join(&p) else { panic!() };
        assert!(*swap_build, "small probe side should become the build side");
        let PhysicalPlan::Filter { predicate, .. } = left.as_ref() else {
            panic!("pushed filter on left side, got {left:?}")
        };
        assert_eq!(predicate.to_sql(), "(small.v < 10.0)");
    }

    #[test]
    fn join_pushdown_declines_right_side_of_left_join() {
        let cat = catalog();
        let udfs = UdfRegistry::new();
        let (p, report) = rewrite_plan(
            &plan(
                "SELECT small.k FROM small LEFT JOIN big ON small.k = big.k \
                 WHERE big.v < 10.0",
            ),
            Some(&cat),
            &udfs,
        );
        assert!(!fired(&report, "join-pushdown"), "{report:?}");
        assert!(matches!(
            p,
            PhysicalPlan::Project { .. } | PhysicalPlan::Filter { .. }
        ));
    }

    #[test]
    fn rewrite_without_catalog_only_structural_rules() {
        let udfs = UdfRegistry::new();
        let (_, report) = rewrite_plan(
            &plan("SELECT small.k FROM small JOIN big ON small.k = big.k WHERE small.v < 1.0"),
            None,
            &udfs,
        );
        for f in &report.fired {
            assert!(
                matches!(f.rule, "constant-elim" | "predicate-pushdown"),
                "stats-dependent rule fired without a catalog: {f:?}"
            );
        }
    }

    #[test]
    fn explain_format_is_stable() {
        let cat = catalog();
        let udfs = UdfRegistry::new();
        let text = explain_plan(&plan("SELECT k FROM t WHERE v < 2.0"), Some(&cat), &udfs);
        assert!(text.contains("project [k]"), "{text}");
        assert!(text.contains("scan t where (v < 2.0)"), "{text}");
        assert!(text.contains("rows"), "{text}");
        assert!(text.contains("rules fired:"), "{text}");
        assert!(text.contains("scan-embed"), "{text}");
        // Shape-independence: nothing about nodes/parallelism appears.
        assert!(!text.contains("nodes"), "{text}");
    }

    #[test]
    fn est_rows_tracks_selectivity_and_limits() {
        let cat = catalog();
        let scan = PhysicalPlan::Scan {
            table: "t".into(),
            alias: None,
            predicate: None,
            live: None,
        };
        assert_eq!(est_rows(&scan, &cat), Some(8192.0));
        let lim = PhysicalPlan::Limit { input: Box::new(scan), n: 10 };
        assert_eq!(est_rows(&lim, &cat), Some(10.0));
    }
}
