//! Deterministic fault injection, retry bookkeeping, and cooperative
//! cancellation for the distributed execution path.
//!
//! The paper's CTC case study (§V.A) is a reliability story: the
//! remote-Spark baseline suffered "frequent job failures, impacting
//! critical SLAs," and moving compute in-situ "resolved the reliability
//! issues." `sim/remote.rs` models that only for the *competitor*; this
//! module gives our own warehouse dispatch the managed-service failure
//! semantics — so `engine/exec.rs::dispatch_morsels` can retry a failed
//! node span with capped backoff, blacklist repeat offenders, degrade to
//! the leader, and honor per-query deadlines. The PR 10 shuffle's
//! per-partition dispatch (`exec::dispatch_partitions`) runs its
//! shipment gauntlet through the same scope: a blacklisted partition
//! owner's partitions reroute to surviving nodes (ultimately the
//! leader) before any state is consumed, so recovery never replays a
//! partial merge.
//!
//! Everything is deterministic: a [`FaultPlan`] is parsed from a seeded
//! spec string (`SNOWPARK_FAULT_PLAN` / `run-sql --fault-plan`) and fires
//! either on the first *K* attempts of a (kind, node) pair or on a seeded
//! hash of the attempt number — the same plan produces the same fault
//! sequence on every platform, which is what lets the differential suite
//! assert byte-identical output under chaos.
//!
//! Design invariant: **node 0 (the leader) is never fault-injected** and
//! its failures are never treated as retryable. The leader is the
//! coordinator — it holds the source columns and runs the merge steps —
//! so leader-only execution is always a sound degraded mode, and every
//! retry loop terminates because each remote is blacklisted after
//! [`MAX_NODE_FAILURES`] failures.

use std::collections::{BTreeMap, HashMap, HashSet};
use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::util::clock::{Clock, WallClock};
use crate::util::rng::Rng;

/// Remote-node failures tolerated before the node is blacklisted: the
/// first failure earns one same-node retry (transient blip), the second
/// reroutes the span to a surviving node (persistent fault).
pub const MAX_NODE_FAILURES: u32 = 2;

/// Maximum capped-exponential backoff exponent (1ms << 3 = 8ms cap).
const MAX_BACKOFF_SHIFT: u32 = 3;

/// Granularity of interruptible sleeps: slow-node delays and backoffs
/// sleep in chunks this size, checking the cancellation token between
/// chunks so a deadline cuts even a long injected stall short.
const SLEEP_CHUNK: Duration = Duration::from_millis(5);

/// Which dispatch step a fault strikes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum FaultKind {
    /// The span shipment to the remote fails before any bytes move.
    Ship,
    /// The remote evaluation fails after the span was shipped.
    Eval,
    /// The remote evaluation panics (worker unwinds mid-task).
    Panic,
}

impl fmt::Display for FaultKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultKind::Ship => write!(f, "ship"),
            FaultKind::Eval => write!(f, "eval"),
            FaultKind::Panic => write!(f, "panic"),
        }
    }
}

/// When a configured fault point fires.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Trigger {
    /// Fire on the first `K` attempts of this (kind, node) pair, then
    /// heal — models a transient outage of known length.
    Count(u64),
    /// Fire with probability `p` on every attempt, decided by a seeded
    /// hash of (seed, kind, node, attempt) — models a flaky node.
    /// Deterministic for a given plan seed.
    Prob(f64),
}

/// A seeded, declarative set of fault points for one execution scope.
///
/// Spec grammar (entries separated by `;` or `,`):
///
/// ```text
/// seed=S          plan seed for probabilistic triggers
/// ship=NODE:TRIG  span shipment to NODE fails
/// eval=NODE:TRIG  remote evaluation on NODE fails
/// panic=NODE:TRIG remote evaluation on NODE panics
/// slow=NODE:MS    every dispatch to NODE stalls MS milliseconds
/// ```
///
/// `TRIG` is either an integer `K` (first K attempts fail) or `pF`
/// (each attempt fails with probability F, e.g. `p0.3`). Node 0 is the
/// leader and is rejected at parse time. Example:
/// `seed=7;ship=1:2;slow=1:1`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// Seed for probabilistic triggers.
    pub seed: u64,
    /// Ship-failure points, keyed by node.
    pub ship: BTreeMap<usize, Trigger>,
    /// Remote-eval failure points, keyed by node.
    pub eval: BTreeMap<usize, Trigger>,
    /// Remote-eval panic points, keyed by node.
    pub panic: BTreeMap<usize, Trigger>,
    /// Slow-node delays in milliseconds, keyed by node.
    pub slow: BTreeMap<usize, u64>,
}

impl FaultPlan {
    /// Parse a spec string (see the type-level grammar). Empty entries
    /// are skipped, so `""` parses to an empty plan.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for entry in spec.split([';', ',']) {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            let (key, val) = entry
                .split_once('=')
                .ok_or_else(|| anyhow!("fault entry {entry:?}: expected key=value"))?;
            match key.trim() {
                "seed" => {
                    plan.seed = val
                        .trim()
                        .parse()
                        .map_err(|_| anyhow!("fault entry {entry:?}: seed must be an integer"))?;
                }
                "ship" => {
                    let (node, trig) = parse_node_trigger(entry, val)?;
                    plan.ship.insert(node, trig);
                }
                "eval" => {
                    let (node, trig) = parse_node_trigger(entry, val)?;
                    plan.eval.insert(node, trig);
                }
                "panic" => {
                    let (node, trig) = parse_node_trigger(entry, val)?;
                    plan.panic.insert(node, trig);
                }
                "slow" => {
                    let (node, ms) = val
                        .split_once(':')
                        .ok_or_else(|| anyhow!("fault entry {entry:?}: expected slow=NODE:MS"))?;
                    let node = parse_remote_node(entry, node)?;
                    let ms: u64 = ms
                        .trim()
                        .parse()
                        .map_err(|_| anyhow!("fault entry {entry:?}: MS must be an integer"))?;
                    plan.slow.insert(node, ms);
                }
                other => bail!(
                    "fault entry {entry:?}: unknown kind {other:?} \
                     (expected seed/ship/eval/panic/slow)"
                ),
            }
        }
        Ok(plan)
    }

    /// True when the plan has no fault points (a bare `seed=S` spec).
    pub fn is_empty(&self) -> bool {
        self.ship.is_empty()
            && self.eval.is_empty()
            && self.panic.is_empty()
            && self.slow.is_empty()
    }
}

fn parse_remote_node(entry: &str, s: &str) -> Result<usize> {
    let node: usize = s
        .trim()
        .parse()
        .map_err(|_| anyhow!("fault entry {entry:?}: NODE must be an integer"))?;
    if node == 0 {
        bail!("fault entry {entry:?}: node 0 is the leader and cannot be fault-injected");
    }
    Ok(node)
}

fn parse_node_trigger(entry: &str, v: &str) -> Result<(usize, Trigger)> {
    let (node, t) = v
        .split_once(':')
        .ok_or_else(|| anyhow!("fault entry {entry:?}: expected KIND=NODE:TRIGGER"))?;
    let node = parse_remote_node(entry, node)?;
    let t = t.trim();
    let trig = if let Some(p) = t.strip_prefix('p') {
        let p: f64 = p
            .parse()
            .map_err(|_| anyhow!("fault entry {entry:?}: probability must be a number"))?;
        if !(0.0..=1.0).contains(&p) {
            bail!("fault entry {entry:?}: probability must be in [0, 1]");
        }
        Trigger::Prob(p)
    } else {
        Trigger::Count(
            t.parse()
                .map_err(|_| anyhow!("fault entry {entry:?}: trigger must be an integer or pF"))?,
        )
    };
    Ok((node, trig))
}

/// Seeded uniform [0,1) hash of a (seed, kind, node, attempt) tuple —
/// one SplitMix64 draw from a well-mixed state, stable across platforms.
fn hash_unit(seed: u64, kind: FaultKind, node: usize, attempt: u64) -> f64 {
    let mut rng = Rng::new(
        seed ^ (kind as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ (node as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9)
            ^ attempt.wrapping_mul(0x94D0_49BB_1331_11EB),
    );
    rng.f64()
}

/// The error produced when a configured fault point fires (or an injected
/// panic is caught). [`is_retryable`] recognizes it, so dispatch retries
/// the span; every other error is terminal.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InjectedFault {
    /// Node the fault struck.
    pub node: usize,
    /// Which dispatch step it struck.
    pub kind: FaultKind,
}

impl fmt::Display for InjectedFault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "injected {} fault on node {}", self.kind, self.node)
    }
}

impl std::error::Error for InjectedFault {}

/// The error a deadline-bound query returns when its cancellation token
/// fires: terminal, never retried.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeadlineExceeded;

impl fmt::Display for DeadlineExceeded {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "query deadline exceeded")
    }
}

impl std::error::Error for DeadlineExceeded {}

/// True when `e` is an [`InjectedFault`] — the only error class the
/// dispatch retry loop is allowed to retry.
pub fn is_retryable(e: &anyhow::Error) -> bool {
    e.downcast_ref::<InjectedFault>().is_some()
}

/// True when `e` is a [`DeadlineExceeded`].
pub fn is_deadline_exceeded(e: &anyhow::Error) -> bool {
    e.downcast_ref::<DeadlineExceeded>().is_some()
}

/// Cooperative cancellation token, checked at morsel boundaries
/// (`morsel.rs::run_stealing_cancellable`), operator entry, and inside
/// fault-injected sleeps. Cloning shares the flag; a deadline latches
/// into the flag the first time it is observed expired.
#[derive(Debug, Clone, Default)]
pub struct CancelToken {
    flag: Arc<AtomicBool>,
    deadline: Option<Instant>,
}

impl CancelToken {
    /// A token that only fires on an explicit [`CancelToken::cancel`].
    pub fn new() -> Self {
        Self::default()
    }

    /// A token that fires `timeout` from now (or on explicit cancel).
    pub fn with_deadline(timeout: Duration) -> Self {
        Self { flag: Arc::default(), deadline: Some(Instant::now() + timeout) }
    }

    /// Cancel explicitly; every clone observes it.
    pub fn cancel(&self) {
        self.flag.store(true, Ordering::SeqCst);
    }

    /// True once cancelled or past the deadline (latching).
    pub fn cancelled(&self) -> bool {
        if self.flag.load(Ordering::Relaxed) {
            return true;
        }
        if let Some(d) = self.deadline {
            if Instant::now() >= d {
                self.flag.store(true, Ordering::SeqCst);
                return true;
            }
        }
        false
    }

    /// `Err(DeadlineExceeded)` once cancelled, `Ok(())` otherwise.
    pub fn check(&self) -> Result<()> {
        if self.cancelled() {
            Err(DeadlineExceeded.into())
        } else {
            Ok(())
        }
    }
}

#[derive(Debug, Default)]
struct ScopeState {
    /// Attempt counters per (kind, node) — drive Count/Prob triggers.
    attempts: HashMap<(FaultKind, usize), u64>,
    /// Failures observed per node (injected or caught), across retries.
    failures: HashMap<usize, u32>,
    /// Nodes excluded from further dispatch this scope.
    blacklist: HashSet<usize>,
}

/// Live fault-injection state for one execution scope (one
/// [`crate::engine::ExecContext`]): the plan plus attempt counters,
/// per-node failure counts, and the blacklist that dispatch consults
/// when rerouting failed spans. Shared across the node-span threads of
/// every dispatch in the scope.
pub struct FaultScope {
    plan: FaultPlan,
    clock: Arc<dyn Clock>,
    state: Mutex<ScopeState>,
}

impl fmt::Debug for FaultScope {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("FaultScope").field("plan", &self.plan).finish_non_exhaustive()
    }
}

impl FaultScope {
    /// A scope over `plan` on the wall clock (the execution default).
    pub fn new(plan: FaultPlan) -> Arc<Self> {
        Self::with_clock(plan, Arc::new(WallClock::new()))
    }

    /// A scope whose injected delays and backoffs run on `clock` —
    /// tests pass a [`crate::util::clock::SimClock`] so slow-node stalls
    /// cost no real time.
    pub fn with_clock(plan: FaultPlan, clock: Arc<dyn Clock>) -> Arc<Self> {
        Arc::new(Self { plan, clock, state: Mutex::new(ScopeState::default()) })
    }

    /// The plan this scope executes.
    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Decide whether the next attempt of (kind, node) faults; consumes
    /// one attempt number either way. Node 0 never faults.
    fn fire(&self, kind: FaultKind, node: usize) -> bool {
        if node == 0 {
            return false;
        }
        let map = match kind {
            FaultKind::Ship => &self.plan.ship,
            FaultKind::Eval => &self.plan.eval,
            FaultKind::Panic => &self.plan.panic,
        };
        let Some(&trig) = map.get(&node) else {
            return false;
        };
        let attempt = {
            let mut st = self.state.lock().unwrap();
            let c = st.attempts.entry((kind, node)).or_insert(0);
            let cur = *c;
            *c += 1;
            cur
        };
        match trig {
            Trigger::Count(k) => attempt < k,
            Trigger::Prob(p) => hash_unit(self.plan.seed, kind, node, attempt) < p,
        }
    }

    /// Ship-failure hook: call before encoding a span for `node`.
    pub fn check_ship(&self, node: usize) -> Result<()> {
        if self.fire(FaultKind::Ship, node) {
            return Err(InjectedFault { node, kind: FaultKind::Ship }.into());
        }
        Ok(())
    }

    /// Remote-eval hook: call after shipping, before evaluating. A
    /// configured panic point unwinds here (the dispatch retry loop
    /// catches it); an eval point returns an [`InjectedFault`].
    pub fn check_eval(&self, node: usize) -> Result<()> {
        if self.fire(FaultKind::Panic, node) {
            panic!("injected panic on node {node}");
        }
        if self.fire(FaultKind::Eval, node) {
            return Err(InjectedFault { node, kind: FaultKind::Eval }.into());
        }
        Ok(())
    }

    /// The configured slow-node stall for `node`, if any.
    pub fn slow_delay(&self, node: usize) -> Option<Duration> {
        if node == 0 {
            return None;
        }
        self.plan.slow.get(&node).map(|&ms| Duration::from_millis(ms))
    }

    /// Sleep `d` on the scope clock in [`SLEEP_CHUNK`] steps, bailing
    /// with [`DeadlineExceeded`] as soon as `cancel` fires — a 60s
    /// injected stall costs a deadline-bound query at most one chunk.
    pub fn sleep_cancellable(&self, d: Duration, cancel: Option<&CancelToken>) -> Result<()> {
        let mut left = d;
        loop {
            if let Some(c) = cancel {
                c.check()?;
            }
            if left.is_zero() {
                return Ok(());
            }
            let step = left.min(SLEEP_CHUNK);
            self.clock.sleep(step);
            left -= step;
        }
    }

    /// Capped exponential backoff before retry number `tries` (1-based):
    /// 1ms, 2ms, 4ms, then 8ms forever. Interruptible by `cancel`.
    pub fn backoff(&self, tries: u32, cancel: Option<&CancelToken>) -> Result<()> {
        let ms = 1u64 << tries.saturating_sub(1).min(MAX_BACKOFF_SHIFT);
        self.sleep_cancellable(Duration::from_millis(ms), cancel)
    }

    /// Record a failure on `node`; blacklist it at [`MAX_NODE_FAILURES`].
    /// Returns true exactly once per node: on the call that transitioned
    /// it into the blacklist. Node 0 is never counted or blacklisted.
    pub fn note_failure(&self, node: usize) -> bool {
        if node == 0 {
            return false;
        }
        let mut guard = self.state.lock().unwrap();
        let st = &mut *guard;
        let c = st.failures.entry(node).or_insert(0);
        *c += 1;
        *c >= MAX_NODE_FAILURES && st.blacklist.insert(node)
    }

    /// True when `node` has been blacklisted this scope.
    pub fn is_blacklisted(&self, node: usize) -> bool {
        self.state.lock().unwrap().blacklist.contains(&node)
    }

    /// Number of nodes blacklisted so far.
    pub fn blacklisted_count(&self) -> usize {
        self.state.lock().unwrap().blacklist.len()
    }

    /// Pick a replacement target for a span whose node `failed`: the
    /// next surviving remote in cyclic order, or the leader (node 0)
    /// when every remote is blacklisted. `nodes` is the dispatch
    /// fan-out; `failed` must be a remote (>= 1).
    pub fn reroute(&self, nodes: usize, failed: usize) -> usize {
        if nodes <= 1 || failed == 0 {
            return 0;
        }
        let st = self.state.lock().unwrap();
        for step in 1..nodes {
            let cand = (failed - 1 + step) % (nodes - 1) + 1;
            if cand != failed && !st.blacklist.contains(&cand) {
                return cand;
            }
        }
        0
    }
}

/// The ambient fault scope from `SNOWPARK_FAULT_PLAN`, if set and
/// non-empty. Malformed specs warn to stderr and are ignored rather than
/// failing every query — chaos tooling should never take down a correct
/// run. `None` is the zero-overhead default: dispatch takes the plain
/// path with no counters, catches, or sleeps.
/// Deprecation shim over [`super::config::EngineConfig::from_env`].
pub fn default_fault_scope() -> Option<Arc<FaultScope>> {
    super::config::EngineConfig::from_env().fault_plan.map(FaultScope::new)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::SimClock;

    #[test]
    fn parse_grammar_round_trips() {
        let p = FaultPlan::parse("seed=7; ship=1:2, eval=2:p0.25; panic=3:1; slow=1:40").unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.ship.get(&1), Some(&Trigger::Count(2)));
        assert_eq!(p.eval.get(&2), Some(&Trigger::Prob(0.25)));
        assert_eq!(p.panic.get(&3), Some(&Trigger::Count(1)));
        assert_eq!(p.slow.get(&1), Some(&40));
        assert!(!p.is_empty());
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("seed=9").unwrap().is_empty());
    }

    #[test]
    fn parse_rejects_leader_unknown_kinds_and_bad_numbers() {
        assert!(FaultPlan::parse("ship=0:1").unwrap_err().to_string().contains("leader"));
        assert!(FaultPlan::parse("slow=0:10").is_err());
        assert!(FaultPlan::parse("frob=1:1").is_err());
        assert!(FaultPlan::parse("ship=1").is_err());
        assert!(FaultPlan::parse("ship=1:p1.5").is_err());
        assert!(FaultPlan::parse("ship=x:1").is_err());
        assert!(FaultPlan::parse("nonsense").is_err());
    }

    #[test]
    fn count_trigger_fires_first_k_then_heals() {
        let scope = FaultScope::new(FaultPlan::parse("ship=1:2").unwrap());
        assert!(scope.check_ship(1).is_err());
        assert!(scope.check_ship(1).is_err());
        assert!(scope.check_ship(1).is_ok());
        assert!(scope.check_ship(1).is_ok());
        // Other nodes and kinds are untouched.
        assert!(scope.check_ship(2).is_ok());
        assert!(scope.check_eval(1).is_ok());
    }

    #[test]
    fn prob_trigger_is_deterministic_per_seed() {
        let decide = |seed: u64| -> Vec<bool> {
            let scope =
                FaultScope::new(FaultPlan::parse(&format!("seed={seed};eval=1:p0.5")).unwrap());
            (0..32).map(|_| scope.check_eval(1).is_err()).collect()
        };
        assert_eq!(decide(3), decide(3));
        assert_ne!(decide(3), decide(4));
        let fired = decide(3).iter().filter(|&&b| b).count();
        assert!(fired > 4 && fired < 28, "p0.5 fired {fired}/32");
    }

    #[test]
    #[should_panic(expected = "injected panic on node 2")]
    fn panic_trigger_unwinds() {
        let scope = FaultScope::new(FaultPlan::parse("panic=2:1").unwrap());
        let _ = scope.check_eval(2);
    }

    #[test]
    fn repeated_failures_blacklist_and_reroute_skips_them() {
        let scope = FaultScope::new(FaultPlan::default());
        assert!(!scope.note_failure(1));
        assert!(!scope.is_blacklisted(1));
        assert!(scope.note_failure(1)); // second failure: blacklisted now
        assert!(!scope.note_failure(1)); // transition reported only once
        assert!(scope.is_blacklisted(1));
        assert_eq!(scope.blacklisted_count(), 1);
        // Rerouting node 1's span at fan-out 4 lands on the next remote.
        assert_eq!(scope.reroute(4, 1), 2);
        scope.note_failure(2);
        scope.note_failure(2);
        assert_eq!(scope.reroute(4, 2), 3);
        scope.note_failure(3);
        scope.note_failure(3);
        // All remotes dead: degrade to the leader.
        assert_eq!(scope.reroute(4, 3), 0);
        assert_eq!(scope.reroute(2, 1), 0);
    }

    #[test]
    fn leader_is_immune() {
        let scope = FaultScope::new(FaultPlan::parse("ship=1:9").unwrap());
        assert!(scope.check_ship(0).is_ok());
        assert!(scope.check_eval(0).is_ok());
        assert_eq!(scope.slow_delay(0), None);
        assert!(!scope.note_failure(0));
        assert!(!scope.is_blacklisted(0));
    }

    #[test]
    fn backoff_is_capped_exponential_on_the_scope_clock() {
        let clock = SimClock::new();
        let scope = FaultScope::with_clock(FaultPlan::default(), Arc::new(clock.clone()));
        let mut slept = Vec::new();
        for tries in 1..=5 {
            let before = clock.now();
            scope.backoff(tries, None).unwrap();
            slept.push((clock.now() - before).as_millis());
        }
        assert_eq!(slept, vec![1, 2, 4, 8, 8]);
    }

    #[test]
    fn cancel_cuts_injected_stall_short() {
        let clock = SimClock::new();
        let scope = FaultScope::with_clock(FaultPlan::default(), Arc::new(clock.clone()));
        let token = CancelToken::new();
        token.cancel();
        let err = scope.sleep_cancellable(Duration::from_secs(60), Some(&token)).unwrap_err();
        assert!(is_deadline_exceeded(&err));
        assert_eq!(clock.now(), Duration::ZERO);
        // Without a token the stall runs to completion (on the sim clock).
        scope.sleep_cancellable(Duration::from_millis(12), None).unwrap();
        assert_eq!(clock.now(), Duration::from_millis(12));
    }

    #[test]
    fn deadline_token_latches() {
        let t = CancelToken::with_deadline(Duration::ZERO);
        assert!(t.cancelled());
        assert!(t.cancelled());
        assert!(is_deadline_exceeded(&t.check().unwrap_err()));
        let open = CancelToken::with_deadline(Duration::from_secs(3600));
        assert!(!open.cancelled());
        assert!(open.check().is_ok());
        let shared = open.clone();
        shared.cancel();
        assert!(open.cancelled());
    }

    #[test]
    fn error_classification() {
        let inj: anyhow::Error = InjectedFault { node: 1, kind: FaultKind::Eval }.into();
        assert!(is_retryable(&inj));
        assert!(!is_deadline_exceeded(&inj));
        assert_eq!(inj.to_string(), "injected eval fault on node 1");
        let dl: anyhow::Error = DeadlineExceeded.into();
        assert!(is_deadline_exceeded(&dl));
        assert!(!is_retryable(&dl));
        let other = anyhow!("real failure");
        assert!(!is_retryable(&other) && !is_deadline_exceeded(&other));
    }
}
