//! Plan execution: vectorized operators over rowsets.
//!
//! The heavy operators (aggregate, join, sort) run on the columnar key
//! codec in [`super::hash`]: group/join keys are encoded once per batch
//! into flat fixed-stride byte rows with precomputed hashes, grouping and
//! probing compare `&[u8]` slices, and aggregation runs typed grouped
//! kernels over raw `&[i64]`/`&[f64]` column slices. Output
//! materialization goes through typed gathers (`RowSet::gather`) instead
//! of per-cell `Value` round trips.
//!
//! Expressions (projections, predicates, group/join/sort keys) run on the
//! columnar kernels in `engine::expr`; residual join predicates evaluate
//! over the `l_idx`/`r_idx` gather vectors on only their referenced
//! columns, before the wide output is materialized.
//!
//! The legacy row-at-a-time paths (including row-wise expression
//! evaluation) are kept behind `ExecContext::vectorized = false` for
//! differential tests and the `groupby_kernels`/`expr_kernels` ablations
//! (`benches/ablations.rs`).

use std::cmp::Ordering;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::sql::ast::{Expr, JoinKind, OrderKey};
use crate::types::{Column, DataType, Field, RowSet, Schema, Value};
use crate::udf::{UdfRegistry, UdfStatsStore};

use super::catalog::Catalog;
use super::expr::{
    eval_expr, eval_expr_rowwise, eval_predicate, eval_predicate_rowwise, eval_row,
    resolve_column,
};
use super::hash::{assign_group_ids, EncodedKeys, JoinTable, KeyDict, KeyMode};
use super::key::KeyValue;
use super::plan::{AggCall, AggFunc, Plan};

/// Everything an operator needs at execution time.
pub struct ExecContext {
    /// Table catalog queries scan from.
    pub catalog: Arc<Catalog>,
    /// Registered user-defined functions (scalar/vectorized/table/agg).
    pub udfs: Arc<UdfRegistry>,
    /// Historical per-UDF cost statistics (feeds the §IV.C decision).
    pub udf_stats: Arc<UdfStatsStore>,
    /// Run expressions on the columnar kernels and aggregate/join/sort on
    /// the columnar key codec (the default). The row-at-a-time paths
    /// remain for differential testing and the `groupby_kernels` /
    /// `expr_kernels` ablations.
    pub vectorized: bool,
}

impl ExecContext {
    /// Context with the default (vectorized) execution paths.
    pub fn new(catalog: Arc<Catalog>, udfs: Arc<UdfRegistry>) -> Self {
        Self {
            catalog,
            udfs,
            udf_stats: Arc::new(UdfStatsStore::new()),
            vectorized: true,
        }
    }

    /// Toggle the vectorized paths (expressions + key codec) on or off.
    pub fn with_vectorized(mut self, on: bool) -> Self {
        self.vectorized = on;
        self
    }
}

/// Evaluate an expression through the path selected by `ctx.vectorized`.
fn eval(e: &Expr, rows: &RowSet, ctx: &ExecContext) -> Result<Column> {
    if ctx.vectorized {
        eval_expr(e, rows, &ctx.udfs)
    } else {
        eval_expr_rowwise(e, rows, &ctx.udfs)
    }
}

/// Evaluate a predicate mask through the path selected by `ctx.vectorized`.
fn eval_pred(e: &Expr, rows: &RowSet, ctx: &ExecContext) -> Result<Vec<bool>> {
    if ctx.vectorized {
        eval_predicate(e, rows, &ctx.udfs)
    } else {
        eval_predicate_rowwise(e, rows, &ctx.udfs)
    }
}

/// Rows processed and wall time spent in one operator class.
#[derive(Debug, Default, Clone, Copy)]
pub struct OpStats {
    /// How many times this operator class ran in the query.
    pub invocations: u64,
    /// Total input rows across invocations.
    pub rows_in: u64,
    /// Total output rows across invocations.
    pub rows_out: u64,
    /// Total wall time in nanoseconds.
    pub nanos: u64,
}

impl OpStats {
    fn record(&mut self, rows_in: u64, rows_out: u64, started: Instant) {
        self.invocations += 1;
        self.rows_in += rows_in;
        self.rows_out += rows_out;
        self.nanos += started.elapsed().as_nanos() as u64;
    }
}

/// Per-query execution statistics: per-operator row counts and timings.
#[derive(Debug, Default, Clone)]
pub struct QueryStats {
    /// Rows read by all table scans.
    pub rows_scanned: u64,
    /// Rows in the query's final result.
    pub rows_output: u64,
    /// Scan / table-function operator stats.
    pub scan: OpStats,
    /// Filter (WHERE / HAVING) operator stats.
    pub filter: OpStats,
    /// Projection operator stats.
    pub project: OpStats,
    /// Hash-aggregate operator stats.
    pub aggregate: OpStats,
    /// Join operator stats.
    pub join: OpStats,
    /// Sort / top-k operator stats.
    pub sort: OpStats,
    /// Limit operator stats.
    pub limit: OpStats,
}

impl QueryStats {
    fn operators(&self) -> [(&'static str, &OpStats); 7] {
        [
            ("scan", &self.scan),
            ("filter", &self.filter),
            ("project", &self.project),
            ("aggregate", &self.aggregate),
            ("join", &self.join),
            ("sort", &self.sort),
            ("limit", &self.limit),
        ]
    }

    /// Aligned per-operator report (`snowparkd run-sql --stats` prints it).
    pub fn report(&self) -> String {
        let mut out = format!(
            "{:<10} {:>6} {:>12} {:>12} {:>12}\n",
            "operator", "calls", "rows_in", "rows_out", "time"
        );
        for (name, op) in self.operators() {
            if op.invocations == 0 {
                continue;
            }
            out.push_str(&format!(
                "{:<10} {:>6} {:>12} {:>12} {:>9.3}ms\n",
                name,
                op.invocations,
                op.rows_in,
                op.rows_out,
                op.nanos as f64 / 1e6
            ));
        }
        out
    }
}

/// Execute a plan to completion.
pub fn execute_plan(plan: &Plan, ctx: &ExecContext) -> Result<RowSet> {
    Ok(execute_plan_with_stats(plan, ctx)?.0)
}

/// Execute a plan, returning per-operator row counts and timings.
pub fn execute_plan_with_stats(plan: &Plan, ctx: &ExecContext) -> Result<(RowSet, QueryStats)> {
    let mut stats = QueryStats::default();
    let out = exec(plan, ctx, &mut stats)?;
    stats.rows_output = out.num_rows() as u64;
    Ok((out, stats))
}

fn exec(plan: &Plan, ctx: &ExecContext, stats: &mut QueryStats) -> Result<RowSet> {
    match plan {
        Plan::Scan { table, alias: _ } => {
            let t0 = Instant::now();
            let rs = ctx.catalog.get(table)?;
            let n = rs.num_rows() as u64;
            stats.rows_scanned += n;
            stats.scan.record(n, n, t0);
            Ok(rs)
        }
        Plan::TableFunc { name, args, alias: _ } => {
            let t0 = Instant::now();
            let rs = if name == "__dual" {
                // SELECT without FROM: one row, zero columns.
                RowSet::new(
                    Schema::new(vec![Field::new("__dummy", DataType::Int64)]),
                    vec![Column::from_i64(vec![0])],
                )
                .unwrap()
            } else {
                // Evaluate constant args against a dual row.
                let dual = RowSet::new(
                    Schema::new(vec![Field::new("__dummy", DataType::Int64)]),
                    vec![Column::from_i64(vec![0])],
                )
                .unwrap();
                let arg_vals: Vec<Value> = args
                    .iter()
                    .map(|a| eval_row(a, &dual, 0, &ctx.udfs))
                    .collect::<Result<_>>()?;
                ctx.catalog
                    .get(name)
                    .or_else(|_| ctx.udfs.call_udtf(name, &arg_vals))?
            };
            let n = rs.num_rows() as u64;
            stats.scan.record(n, n, t0);
            Ok(rs)
        }
        Plan::Filter { input, predicate } => {
            let rows = exec(input, ctx, stats)?;
            let t0 = Instant::now();
            let mask = eval_pred(predicate, &rows, ctx)?;
            let out = rows.filter(&mask);
            stats
                .filter
                .record(rows.num_rows() as u64, out.num_rows() as u64, t0);
            Ok(out)
        }
        Plan::Project { input, exprs } => {
            let rows = exec(input, ctx, stats)?;
            let t0 = Instant::now();
            let out = project(&rows, exprs, ctx)?;
            stats
                .project
                .record(rows.num_rows() as u64, out.num_rows() as u64, t0);
            Ok(out)
        }
        Plan::Aggregate { input, group, aggs } => {
            let rows = exec(input, ctx, stats)?;
            let t0 = Instant::now();
            let out = aggregate(&rows, group, aggs, ctx)?;
            stats
                .aggregate
                .record(rows.num_rows() as u64, out.num_rows() as u64, t0);
            Ok(out)
        }
        Plan::Join { left, right, kind, equi, residual } => {
            let l = exec(left, ctx, stats)?;
            let r = exec(right, ctx, stats)?;
            let t0 = Instant::now();
            let out = join(&l, &r, *kind, equi, residual.as_ref(), ctx, plan)?;
            stats.join.record(
                (l.num_rows() + r.num_rows()) as u64,
                out.num_rows() as u64,
                t0,
            );
            Ok(out)
        }
        Plan::Sort { input, keys } => {
            let rows = exec(input, ctx, stats)?;
            let t0 = Instant::now();
            let out = sort(&rows, keys, ctx, None)?;
            stats
                .sort
                .record(rows.num_rows() as u64, out.num_rows() as u64, t0);
            Ok(out)
        }
        Plan::Limit { input, n } => {
            // `ORDER BY ... LIMIT k` short-circuits into a top-k partial
            // sort instead of sorting the full input. The sort may sit
            // directly below, or below the hidden-column-dropping
            // projection the planner inserts.
            match input.as_ref() {
                Plan::Sort { input: sort_input, keys } => {
                    let rows = exec(sort_input, ctx, stats)?;
                    let t0 = Instant::now();
                    let out = sort(&rows, keys, ctx, Some(*n))?;
                    stats
                        .sort
                        .record(rows.num_rows() as u64, out.num_rows() as u64, t0);
                    Ok(out)
                }
                Plan::Project { input: proj_input, exprs }
                    if matches!(proj_input.as_ref(), Plan::Sort { .. }) =>
                {
                    if let Plan::Sort { input: sort_input, keys } = proj_input.as_ref() {
                        let rows = exec(sort_input, ctx, stats)?;
                        let t0 = Instant::now();
                        let sorted = sort(&rows, keys, ctx, Some(*n))?;
                        stats
                            .sort
                            .record(rows.num_rows() as u64, sorted.num_rows() as u64, t0);
                        let t0 = Instant::now();
                        let out = project(&sorted, exprs, ctx)?;
                        stats
                            .project
                            .record(sorted.num_rows() as u64, out.num_rows() as u64, t0);
                        Ok(out)
                    } else {
                        unreachable!("guarded by matches! above")
                    }
                }
                _ => {
                    let rows = exec(input, ctx, stats)?;
                    let t0 = Instant::now();
                    let out = rows.slice(0, (*n).min(rows.num_rows()));
                    stats
                        .limit
                        .record(rows.num_rows() as u64, out.num_rows() as u64, t0);
                    Ok(out)
                }
            }
        }
    }
}

fn project(rows: &RowSet, exprs: &[(Expr, String)], ctx: &ExecContext) -> Result<RowSet> {
    let mut fields = Vec::new();
    let mut columns = Vec::new();
    for (e, name) in exprs {
        // Marker from the planner: keep everything except hidden sort keys.
        if matches!(e, Expr::Func { name, .. } if name == "__drop_hidden") {
            for (f, c) in rows.schema.fields.iter().zip(&rows.columns) {
                if !f.name.starts_with("__sort_") {
                    fields.push(f.clone());
                    columns.push(c.clone());
                }
            }
            continue;
        }
        if matches!(e, Expr::Star) {
            // Wildcard expansion mixed with other expressions.
            for (f, c) in rows.schema.fields.iter().zip(&rows.columns) {
                fields.push(f.clone());
                columns.push(c.clone());
            }
            continue;
        }
        let col = eval(e, rows, ctx)?;
        fields.push(Field::new(name.clone(), col.data_type()));
        columns.push(col);
    }
    RowSet::new(Schema::new(fields), columns)
}

// ---------------------------------------------------------------- aggregate

struct GroupState {
    key_row: Vec<Value>,
    accs: Vec<AggAcc>,
}

enum AggAcc {
    CountStar(i64),
    Count(i64),
    /// SUM accumulates exactly in `i64` while every input is an integer,
    /// switching to `f64` on the first float input or on `i64` overflow
    /// (fixes silent precision loss past 2^53).
    Sum { isum: i64, fsum: f64, float_mode: bool, any: bool },
    Avg { sum: f64, n: i64 },
    Min(Option<Value>),
    Max(Option<Value>),
    Udaf(Box<dyn crate::udf::UdafState>),
}

impl AggAcc {
    fn new(call: &AggCall, udfs: &UdfRegistry) -> Result<AggAcc> {
        Ok(match call.func {
            AggFunc::CountStar => AggAcc::CountStar(0),
            AggFunc::Count => AggAcc::Count(0),
            AggFunc::Sum => AggAcc::Sum { isum: 0, fsum: 0.0, float_mode: false, any: false },
            AggFunc::Avg => AggAcc::Avg { sum: 0.0, n: 0 },
            AggFunc::Min => AggAcc::Min(None),
            AggFunc::Max => AggAcc::Max(None),
            AggFunc::Udaf => {
                let udaf = udfs
                    .udaf(&call.name)
                    .ok_or_else(|| anyhow!("no UDAF {:?}", call.name))?;
                AggAcc::Udaf((udaf.factory)())
            }
        })
    }

    fn update(&mut self, args: &[Value]) -> Result<()> {
        match self {
            AggAcc::CountStar(n) => *n += 1,
            AggAcc::Count(n) => {
                if !args[0].is_null() {
                    *n += 1;
                }
            }
            AggAcc::Sum { isum, fsum, float_mode, any } => match &args[0] {
                Value::Null => {}
                Value::Int(i) => {
                    *any = true;
                    if *float_mode {
                        *fsum += *i as f64;
                    } else {
                        match isum.checked_add(*i) {
                            Some(s) => *isum = s,
                            None => {
                                *float_mode = true;
                                *fsum = *isum as f64 + *i as f64;
                            }
                        }
                    }
                }
                v => {
                    let x = v
                        .as_f64()
                        .ok_or_else(|| anyhow!("SUM over non-numeric {v}"))?;
                    *any = true;
                    if !*float_mode {
                        *float_mode = true;
                        *fsum = *isum as f64;
                    }
                    *fsum += x;
                }
            },
            AggAcc::Avg { sum, n } => {
                if !args[0].is_null() {
                    *sum += args[0]
                        .as_f64()
                        .ok_or_else(|| anyhow!("AVG over non-numeric {}", args[0]))?;
                    *n += 1;
                }
            }
            AggAcc::Min(cur) => {
                if !args[0].is_null() {
                    let replace = match cur {
                        None => true,
                        Some(c) => {
                            args[0].sql_cmp(c) == Some(std::cmp::Ordering::Less)
                        }
                    };
                    if replace {
                        *cur = Some(args[0].clone());
                    }
                }
            }
            AggAcc::Max(cur) => {
                if !args[0].is_null() {
                    let replace = match cur {
                        None => true,
                        Some(c) => {
                            args[0].sql_cmp(c) == Some(std::cmp::Ordering::Greater)
                        }
                    };
                    if replace {
                        *cur = Some(args[0].clone());
                    }
                }
            }
            AggAcc::Udaf(state) => state.update(args)?,
        }
        Ok(())
    }

    fn finish(&self) -> Result<Value> {
        Ok(match self {
            AggAcc::CountStar(n) | AggAcc::Count(n) => Value::Int(*n),
            AggAcc::Sum { isum, fsum, float_mode, any } => {
                if !any {
                    Value::Null
                } else if *float_mode {
                    Value::Float(*fsum)
                } else {
                    Value::Int(*isum)
                }
            }
            AggAcc::Avg { sum, n } => {
                if *n == 0 {
                    Value::Null
                } else {
                    Value::Float(sum / *n as f64)
                }
            }
            AggAcc::Min(v) | AggAcc::Max(v) => v.clone().unwrap_or(Value::Null),
            AggAcc::Udaf(state) => state.finish()?,
        })
    }
}

fn aggregate(
    rows: &RowSet,
    group: &[(Expr, String)],
    aggs: &[AggCall],
    ctx: &ExecContext,
) -> Result<RowSet> {
    // Evaluate group keys and aggregate arguments as columns first
    // (vectorized), then group.
    let key_cols: Vec<Column> = group
        .iter()
        .map(|(e, _)| eval(e, rows, ctx))
        .collect::<Result<_>>()?;
    let arg_cols: Vec<Vec<Column>> = aggs
        .iter()
        .map(|a| {
            a.args
                .iter()
                .map(|e| eval(e, rows, ctx))
                .collect::<Result<Vec<_>>>()
        })
        .collect::<Result<_>>()?;
    if ctx.vectorized {
        aggregate_vectorized(rows, group, aggs, &key_cols, &arg_cols, ctx)
    } else {
        aggregate_rowwise(rows, group, aggs, &key_cols, &arg_cols, ctx)
    }
}

/// Two-pass vectorized aggregation: (1) assign each row a dense group id
/// via the key codec, (2) run typed grouped kernels over raw column
/// slices. Group output order is first-seen order, like the legacy path.
fn aggregate_vectorized(
    rows: &RowSet,
    group: &[(Expr, String)],
    aggs: &[AggCall],
    key_cols: &[Column],
    arg_cols: &[Vec<Column>],
    ctx: &ExecContext,
) -> Result<RowSet> {
    let n = rows.num_rows();
    // Pass 1: dense group ids.
    let (group_of, rep_rows, n_groups) = if group.is_empty() {
        // Global aggregation: one group, even over empty input.
        (vec![0u32; n], Vec::new(), 1)
    } else {
        let mut dict = KeyDict::new();
        let keys = EncodedKeys::encode(key_cols, KeyMode::Group, &mut dict);
        let g = assign_group_ids(&keys);
        let n_groups = g.n_groups();
        (g.ids, g.rep_rows, n_groups)
    };

    // Pass 2: key columns gather from the representative rows; aggregates
    // run typed kernels.
    let mut fields = Vec::with_capacity(group.len() + aggs.len());
    let mut columns = Vec::with_capacity(group.len() + aggs.len());
    for ((_, name), col) in group.iter().zip(key_cols) {
        let out = col.take(&rep_rows);
        fields.push(Field::new(name.clone(), out.data_type()));
        columns.push(out);
    }
    for (call, cols) in aggs.iter().zip(arg_cols) {
        let out = agg_kernel(call, cols, &group_of, n_groups, ctx)?;
        fields.push(Field::new(call.out_name.clone(), out.data_type()));
        columns.push(out);
    }
    RowSet::new(Schema::new(fields), columns)
}

/// Dispatch one aggregate call to its typed grouped kernel; UDAFs fall
/// back to the accumulator path (per group, not per row-key).
fn agg_kernel(
    call: &AggCall,
    args: &[Column],
    gids: &[u32],
    n_groups: usize,
    ctx: &ExecContext,
) -> Result<Column> {
    match call.func {
        AggFunc::CountStar => {
            let mut counts = vec![0i64; n_groups];
            for &g in gids {
                counts[g as usize] += 1;
            }
            Ok(Column::from_i64(counts))
        }
        AggFunc::Count => Ok(count_by_group(&args[0], gids, n_groups)),
        AggFunc::Sum => sum_by_group(&args[0], gids, n_groups),
        AggFunc::Avg => avg_by_group(&args[0], gids, n_groups),
        AggFunc::Min => Ok(min_max_by_group(&args[0], gids, n_groups, true)),
        AggFunc::Max => Ok(min_max_by_group(&args[0], gids, n_groups, false)),
        AggFunc::Udaf => udaf_by_group(call, args, gids, n_groups, ctx),
    }
}

/// All-NULL Float64 column — the type the legacy value-derived schema
/// assigned when an aggregate produced no non-NULL value at all.
fn null_f64_column(n: usize) -> Column {
    Column::Float64 {
        data: vec![0.0; n],
        valid: if n > 0 { Some(vec![false; n]) } else { None },
    }
}

/// `None` when every group has a value (no validity mask needed).
fn mask_from_any(any: &[bool]) -> Option<Vec<bool>> {
    if any.iter().all(|&a| a) {
        None
    } else {
        Some(any.to_vec())
    }
}

/// SUM/AVG over a non-numeric column: error on the first non-NULL value
/// (matching the legacy row path); all-NULL input yields NULL sums.
fn non_numeric_agg(what: &str, col: &Column, n_groups: usize) -> Result<Column> {
    for r in 0..col.len() {
        if col.is_valid(r) {
            bail!("{what} over non-numeric {}", col.value(r));
        }
    }
    Ok(null_f64_column(n_groups))
}

fn count_by_group(col: &Column, gids: &[u32], n_groups: usize) -> Column {
    let mut counts = vec![0i64; n_groups];
    match col.validity() {
        None => {
            for &g in gids {
                counts[g as usize] += 1;
            }
        }
        Some(valid) => {
            for (r, &g) in gids.iter().enumerate() {
                if valid[r] {
                    counts[g as usize] += 1;
                }
            }
        }
    }
    Column::from_i64(counts)
}

/// Grouped SUM. Int64 inputs accumulate in `i64` with overflow-checked
/// widening to `f64` (per group; any overflow widens the output column).
fn sum_by_group(col: &Column, gids: &[u32], n_groups: usize) -> Result<Column> {
    match col {
        Column::Int64 { data, valid } => {
            let mut isums = vec![0i64; n_groups];
            // Allocated lazily on the first overflow.
            let mut fsums: Vec<f64> = Vec::new();
            let mut overflowed: Vec<bool> = Vec::new();
            let mut any = vec![false; n_groups];
            for (r, &g) in gids.iter().enumerate() {
                if valid.as_ref().map_or(true, |v| v[r]) {
                    let g = g as usize;
                    any[g] = true;
                    if !overflowed.is_empty() && overflowed[g] {
                        fsums[g] += data[r] as f64;
                    } else {
                        match isums[g].checked_add(data[r]) {
                            Some(s) => isums[g] = s,
                            None => {
                                if overflowed.is_empty() {
                                    overflowed = vec![false; n_groups];
                                    fsums = vec![0.0; n_groups];
                                }
                                overflowed[g] = true;
                                fsums[g] = isums[g] as f64 + data[r] as f64;
                            }
                        }
                    }
                }
            }
            if !any.iter().any(|&a| a) {
                return Ok(null_f64_column(n_groups));
            }
            if overflowed.is_empty() {
                Ok(Column::Int64 { data: isums, valid: mask_from_any(&any) })
            } else {
                // At least one group overflowed i64: widen the column.
                let data: Vec<f64> = (0..n_groups)
                    .map(|g| if overflowed[g] { fsums[g] } else { isums[g] as f64 })
                    .collect();
                Ok(Column::Float64 { data, valid: mask_from_any(&any) })
            }
        }
        Column::Float64 { data, valid } => {
            let mut sums = vec![0.0f64; n_groups];
            let mut any = vec![false; n_groups];
            for (r, &g) in gids.iter().enumerate() {
                if valid.as_ref().map_or(true, |v| v[r]) {
                    sums[g as usize] += data[r];
                    any[g as usize] = true;
                }
            }
            if !any.iter().any(|&a| a) {
                return Ok(null_f64_column(n_groups));
            }
            Ok(Column::Float64 { data: sums, valid: mask_from_any(&any) })
        }
        other => non_numeric_agg("SUM", other, n_groups),
    }
}

fn avg_by_group(col: &Column, gids: &[u32], n_groups: usize) -> Result<Column> {
    let mut sums = vec![0.0f64; n_groups];
    let mut counts = vec![0i64; n_groups];
    match col {
        Column::Int64 { data, valid } => {
            for (r, &g) in gids.iter().enumerate() {
                if valid.as_ref().map_or(true, |v| v[r]) {
                    sums[g as usize] += data[r] as f64;
                    counts[g as usize] += 1;
                }
            }
        }
        Column::Float64 { data, valid } => {
            for (r, &g) in gids.iter().enumerate() {
                if valid.as_ref().map_or(true, |v| v[r]) {
                    sums[g as usize] += data[r];
                    counts[g as usize] += 1;
                }
            }
        }
        other => return non_numeric_agg("AVG", other, n_groups),
    }
    let data: Vec<f64> = sums
        .iter()
        .zip(&counts)
        .map(|(s, &c)| if c > 0 { s / c as f64 } else { 0.0 })
        .collect();
    let any: Vec<bool> = counts.iter().map(|&c| c > 0).collect();
    Ok(Column::Float64 { data, valid: mask_from_any(&any) })
}

/// Grouped MIN/MAX via best-row indices: one typed compare per row, then a
/// single typed gather — no `Value` comparisons, no string clones.
fn min_max_by_group(col: &Column, gids: &[u32], n_groups: usize, is_min: bool) -> Column {
    fn scan_best<F: Fn(usize, usize) -> bool>(
        gids: &[u32],
        valid: Option<&[bool]>,
        best: &mut [i64],
        better: F,
    ) {
        for (r, &g) in gids.iter().enumerate() {
            if valid.map_or(true, |v| v[r]) {
                let b = &mut best[g as usize];
                if *b < 0 || better(r, *b as usize) {
                    *b = r as i64;
                }
            }
        }
    }

    let mut best: Vec<i64> = vec![-1; n_groups];
    let valid = col.validity();
    match col {
        Column::Int64 { data, .. } => scan_best(gids, valid, &mut best, |r, b| {
            if is_min {
                data[r] < data[b]
            } else {
                data[r] > data[b]
            }
        }),
        Column::Float64 { data, .. } => scan_best(gids, valid, &mut best, |r, b| {
            // Mirrors `Value::sql_cmp`: NaN compares as unknown, so it
            // never replaces the current best.
            let ord = data[r].partial_cmp(&data[b]);
            if is_min {
                ord == Some(Ordering::Less)
            } else {
                ord == Some(Ordering::Greater)
            }
        }),
        Column::Utf8 { data, .. } => scan_best(gids, valid, &mut best, |r, b| {
            if is_min {
                data[r] < data[b]
            } else {
                data[r] > data[b]
            }
        }),
        Column::Bool { data, .. } => scan_best(gids, valid, &mut best, |r, b| {
            if is_min {
                !data[r] & data[b]
            } else {
                data[r] & !data[b]
            }
        }),
    }
    if best.iter().all(|&b| b < 0) {
        // No non-NULL input anywhere: legacy schema derivation fell back
        // to Float64.
        return null_f64_column(n_groups);
    }
    col.gather_opt(&best)
}

/// UDAF fallback: accumulator states per dense group id (still avoids the
/// per-row key materialization of the legacy path).
fn udaf_by_group(
    call: &AggCall,
    args: &[Column],
    gids: &[u32],
    n_groups: usize,
    ctx: &ExecContext,
) -> Result<Column> {
    let udaf = ctx
        .udfs
        .udaf(&call.name)
        .ok_or_else(|| anyhow!("no UDAF {:?}", call.name))?;
    let mut states: Vec<Box<dyn crate::udf::UdafState>> =
        (0..n_groups).map(|_| (udaf.factory)()).collect();
    let mut argv: Vec<Value> = Vec::with_capacity(args.len());
    for (r, &g) in gids.iter().enumerate() {
        argv.clear();
        for c in args {
            argv.push(c.value(r));
        }
        states[g as usize].update(&argv)?;
    }
    let mut vals = Vec::with_capacity(n_groups);
    for s in &states {
        vals.push(s.finish()?);
    }
    let mut dt = udaf.return_type;
    if dt == DataType::Int64 && vals.iter().any(|v| matches!(v, Value::Float(_))) {
        dt = DataType::Float64;
    }
    Column::from_values(dt, &vals)
}

/// Legacy row-at-a-time aggregation (kept for differential tests and the
/// codec on/off ablation).
fn aggregate_rowwise(
    rows: &RowSet,
    group: &[(Expr, String)],
    aggs: &[AggCall],
    key_cols: &[Column],
    arg_cols: &[Vec<Column>],
    ctx: &ExecContext,
) -> Result<RowSet> {
    let n = rows.num_rows();
    let mut groups: std::collections::HashMap<Vec<KeyValue>, GroupState> =
        std::collections::HashMap::new();
    // Preserve first-seen group order for deterministic output.
    let mut order: Vec<Vec<KeyValue>> = Vec::new();

    for r in 0..n {
        let key: Vec<KeyValue> = key_cols
            .iter()
            .map(|c| KeyValue::from_value(&c.value(r)))
            .collect();
        let state = match groups.get_mut(&key) {
            Some(s) => s,
            None => {
                let accs = aggs
                    .iter()
                    .map(|a| AggAcc::new(a, &ctx.udfs))
                    .collect::<Result<Vec<_>>>()?;
                let key_row = key_cols.iter().map(|c| c.value(r)).collect();
                order.push(key.clone());
                groups.insert(key.clone(), GroupState { key_row, accs });
                groups.get_mut(&key).unwrap()
            }
        };
        for (acc, cols) in state.accs.iter_mut().zip(arg_cols) {
            let args: Vec<Value> = cols.iter().map(|c| c.value(r)).collect();
            acc.update(&args)?;
        }
    }

    // Global aggregation over empty input still yields one row.
    if group.is_empty() && groups.is_empty() {
        let accs = aggs
            .iter()
            .map(|a| AggAcc::new(a, &ctx.udfs))
            .collect::<Result<Vec<_>>>()?;
        order.push(vec![]);
        groups.insert(vec![], GroupState { key_row: vec![], accs });
    }

    // Materialize output.
    let mut out_values: Vec<Vec<Value>> = Vec::with_capacity(order.len());
    for key in &order {
        let state = &groups[key];
        let mut row = state.key_row.clone();
        for acc in &state.accs {
            row.push(acc.finish()?);
        }
        out_values.push(row);
    }
    let mut fields = Vec::new();
    for ((_, name), col) in group.iter().zip(key_cols) {
        fields.push(Field::new(name.clone(), col.data_type()));
    }
    // Each aggregate's output type is computed once from its own output
    // column (the old code re-scanned `aggs` per produced row, which was
    // quadratic in the number of aggregates times groups).
    for (ai, a) in aggs.iter().enumerate() {
        let dt = match a.func {
            AggFunc::CountStar | AggFunc::Count => DataType::Int64,
            AggFunc::Avg => DataType::Float64,
            AggFunc::Sum | AggFunc::Min | AggFunc::Max => {
                // Derive from produced values; default Float64.
                out_values
                    .iter()
                    .find_map(|row| row[group.len() + ai].data_type())
                    .unwrap_or(DataType::Float64)
            }
            AggFunc::Udaf => ctx
                .udfs
                .udaf(&a.name)
                .map(|u| u.return_type)
                .unwrap_or(DataType::Float64),
        };
        fields.push(Field::new(a.out_name.clone(), dt));
    }
    let schema = Schema::new(fields);
    let n_cols = schema.len();
    let mut columns = Vec::with_capacity(n_cols);
    for c in 0..n_cols {
        let vals: Vec<Value> = out_values.iter().map(|r| r[c].clone()).collect();
        // Widen Int to Float if mixed (e.g. SUM overflow in some groups).
        let dt = if schema.field(c).data_type == DataType::Int64
            && vals.iter().any(|v| matches!(v, Value::Float(_)))
        {
            DataType::Float64
        } else {
            schema.field(c).data_type
        };
        columns.push(Column::from_values(dt, &vals)?);
    }
    let fields = schema
        .fields
        .iter()
        .zip(&columns)
        .map(|(f, c)| Field::new(f.name.clone(), c.data_type()))
        .collect();
    RowSet::new(Schema::new(fields), columns)
}

// --------------------------------------------------------------------- join

/// Build the combined schema for a join, qualifying colliding names.
fn join_schema(l: &RowSet, lalias: &str, r: &RowSet, ralias: &str) -> Schema {
    let mut fields = Vec::new();
    let collides = |name: &str| {
        l.schema.index_of(name).is_some() && r.schema.index_of(name).is_some()
    };
    for f in &l.schema.fields {
        let name = if collides(&f.name) {
            format!("{lalias}.{}", f.name)
        } else {
            f.name.clone()
        };
        fields.push(Field::new(name, f.data_type));
    }
    for f in &r.schema.fields {
        let name = if collides(&f.name) {
            format!("{ralias}.{}", f.name)
        } else {
            f.name.clone()
        };
        fields.push(Field::new(name, f.data_type));
    }
    Schema::new(fields)
}

fn plan_alias(p: &Plan, default: &str) -> String {
    match p {
        Plan::Scan { table, alias } => alias.clone().unwrap_or_else(|| table.clone()),
        Plan::TableFunc { name, alias, .. } => alias.clone().unwrap_or_else(|| name.clone()),
        Plan::Filter { input, .. } | Plan::Limit { input, .. } | Plan::Sort { input, .. } => {
            plan_alias(input, default)
        }
        _ => default.to_string(),
    }
}

/// Hash join (equi) with optional residual filter; falls back to a
/// nested-loop cross product + filter when no equi keys exist. The
/// vectorized path builds its table from codec-encoded keys and probes
/// with `&[u8]` compares; both paths emit `l_idx`/`r_idx` gather vectors
/// that materialize through typed column gathers.
fn join(
    l: &RowSet,
    r: &RowSet,
    kind: JoinKind,
    equi: &[(Expr, Expr)],
    residual: Option<&Expr>,
    ctx: &ExecContext,
    plan: &Plan,
) -> Result<RowSet> {
    let (lalias, ralias) = match plan {
        Plan::Join { left, right, .. } => {
            (plan_alias(left, "l"), plan_alias(right, "r"))
        }
        _ => ("l".to_string(), "r".to_string()),
    };
    let out_schema = join_schema(l, &lalias, r, &ralias);

    // Assign each equi pair's sides: an expression belongs to the side
    // whose schema resolves all its columns.
    let resolvable = |e: &Expr, rs: &RowSet| -> bool {
        let mut cols = Vec::new();
        e.referenced_columns(&mut cols);
        !cols.is_empty() && cols.iter().all(|c| resolve_column(&rs.schema, c).is_ok())
    };
    let mut lkeys: Vec<&Expr> = Vec::new();
    let mut rkeys: Vec<&Expr> = Vec::new();
    for (a, b) in equi {
        if resolvable(a, l) && resolvable(b, r) {
            lkeys.push(a);
            rkeys.push(b);
        } else if resolvable(b, l) && resolvable(a, r) {
            lkeys.push(b);
            rkeys.push(a);
        } else {
            bail!(
                "cannot assign join condition {} = {} to sides",
                a.to_sql(),
                b.to_sql()
            );
        }
    }

    let mut l_idx: Vec<i64> = Vec::new();
    let mut r_idx: Vec<i64> = Vec::new(); // -1 = NULL row (left join)

    if lkeys.is_empty() {
        // Cross product (small inputs only — residual filters after).
        for i in 0..l.num_rows() {
            let mut matched = false;
            for j in 0..r.num_rows() {
                l_idx.push(i as i64);
                r_idx.push(j as i64);
                matched = true;
            }
            if !matched && kind == JoinKind::Left {
                l_idx.push(i as i64);
                r_idx.push(-1);
            }
        }
    } else {
        let rkey_cols: Vec<Column> = rkeys
            .iter()
            .map(|e| eval(e, r, ctx))
            .collect::<Result<_>>()?;
        let lkey_cols: Vec<Column> = lkeys
            .iter()
            .map(|e| eval(e, l, ctx))
            .collect::<Result<_>>()?;
        if ctx.vectorized {
            // One shared dict so equal strings on both sides intern to
            // equal ids; one hash per row, zero key clones.
            let mut dict = KeyDict::new();
            let table =
                JoinTable::build(EncodedKeys::encode(&rkey_cols, KeyMode::Join, &mut dict));
            let probe = EncodedKeys::encode(&lkey_cols, KeyMode::Join, &mut dict);
            for i in 0..l.num_rows() {
                let mut matched = false;
                if !probe.has_null(i) {
                    // SQL join: NULL keys never match.
                    let mut m = table.first_match(probe.key(i), probe.hash(i));
                    while let Some(j) = m {
                        l_idx.push(i as i64);
                        r_idx.push(j as i64);
                        matched = true;
                        m = table.next_match(j);
                    }
                }
                if !matched && kind == JoinKind::Left {
                    l_idx.push(i as i64);
                    r_idx.push(-1);
                }
            }
        } else {
            // Legacy path: per-row KeyValue materialization.
            let mut table: std::collections::HashMap<Vec<KeyValue>, Vec<usize>> =
                std::collections::HashMap::new();
            for j in 0..r.num_rows() {
                let key: Vec<KeyValue> = rkey_cols
                    .iter()
                    .map(|c| KeyValue::join_normalized(&c.value(j)))
                    .collect();
                // SQL join: NULL keys never match.
                if key.iter().any(|k| matches!(k, KeyValue::Null)) {
                    continue;
                }
                table.entry(key).or_default().push(j);
            }
            for i in 0..l.num_rows() {
                let key: Vec<KeyValue> = lkey_cols
                    .iter()
                    .map(|c| KeyValue::join_normalized(&c.value(i)))
                    .collect();
                let matches = if key.iter().any(|k| matches!(k, KeyValue::Null)) {
                    None
                } else {
                    table.get(&key)
                };
                match matches {
                    Some(js) => {
                        for &j in js {
                            l_idx.push(i as i64);
                            r_idx.push(j as i64);
                        }
                    }
                    None => {
                        if kind == JoinKind::Left {
                            l_idx.push(i as i64);
                            r_idx.push(-1);
                        }
                    }
                }
            }
        }
    }

    // Residual predicate, evaluated BEFORE materialization: only the
    // columns the predicate references are gathered through the
    // `l_idx`/`r_idx` vectors, the mask compacts the index vectors, and
    // rows the residual drops are never gathered into the wide output.
    // (Left-join NULL-row preservation caveat as before: a left row whose
    // every match fails the residual is dropped, not re-NULL-padded.)
    let (l_idx, r_idx) = match residual {
        Some(pred) => {
            let mask = residual_mask(pred, l, r, &out_schema, &l_idx, &r_idx, ctx)?;
            let mut fl = Vec::with_capacity(l_idx.len());
            let mut fr = Vec::with_capacity(r_idx.len());
            for (k, keep) in mask.iter().enumerate() {
                if *keep {
                    fl.push(l_idx[k]);
                    fr.push(r_idx[k]);
                }
            }
            (fl, fr)
        }
        None => (l_idx, r_idx),
    };

    // Materialize the combined rowset through typed gathers.
    materialize_join(l, r, &out_schema, &l_idx, &r_idx)
}

/// Evaluate a residual join predicate over the gather vectors without
/// materializing the full combined rowset: resolve the predicate's
/// referenced columns against the combined schema, gather only those,
/// and return the keep-mask over the candidate matches.
fn residual_mask(
    pred: &Expr,
    l: &RowSet,
    r: &RowSet,
    out_schema: &Schema,
    l_idx: &[i64],
    r_idx: &[i64],
    ctx: &ExecContext,
) -> Result<Vec<bool>> {
    let mut names = Vec::new();
    pred.referenced_columns(&mut names);
    let mut needed: Vec<usize> = names
        .iter()
        .map(|n| resolve_column(out_schema, n))
        .collect::<Result<_>>()?;
    needed.sort_unstable();
    needed.dedup();
    let ln = l.num_columns();
    let mut fields = Vec::with_capacity(needed.len().max(1));
    let mut cols = Vec::with_capacity(needed.len().max(1));
    if needed.is_empty() {
        // Column-free residual (e.g. a constant conjunct): a zero-column
        // rowset would report zero rows, so carry a dummy column that
        // pins the row count to the number of candidate matches.
        fields.push(Field::new("__residual_dummy", DataType::Int64));
        cols.push(Column::from_i64(vec![0; l_idx.len()]));
    }
    for &ci in &needed {
        fields.push(out_schema.field(ci).clone());
        let col = if ci < ln {
            l.column(ci).gather_opt(l_idx)
        } else {
            r.column(ci - ln).gather_opt(r_idx)
        };
        cols.push(col);
    }
    let narrow = RowSet::new(Schema::new(fields), cols)?;
    eval_pred(pred, &narrow, ctx)
}

fn materialize_join(
    l: &RowSet,
    r: &RowSet,
    schema: &Schema,
    l_idx: &[i64],
    r_idx: &[i64],
) -> Result<RowSet> {
    let left = l.gather(l_idx, false);
    let right = r.gather(r_idx, true); // -1 = NULL row (unmatched left rows)
    let mut columns = left.columns;
    columns.extend(right.columns);
    RowSet::new(schema.clone(), columns)
}

// --------------------------------------------------------------------- sort

/// A decorated sort key: raw typed slice + validity + direction, computed
/// once so the comparator never materializes a `Value` (or clones a
/// string) per comparison.
enum SortVals<'a> {
    I64(&'a [i64]),
    F64(&'a [f64]),
    Str(&'a [String]),
    Bool(&'a [bool]),
}

struct SortKeyCol<'a> {
    vals: SortVals<'a>,
    valid: Option<&'a [bool]>,
    descending: bool,
}

fn decorate<'a>(keys: &[OrderKey], cols: &'a [Column]) -> Vec<SortKeyCol<'a>> {
    keys.iter()
        .zip(cols)
        .map(|(k, c)| {
            let vals = match c {
                Column::Int64 { data, .. } => SortVals::I64(data),
                Column::Float64 { data, .. } => SortVals::F64(data),
                Column::Utf8 { data, .. } => SortVals::Str(data),
                Column::Bool { data, .. } => SortVals::Bool(data),
            };
            SortKeyCol { vals, valid: c.validity(), descending: k.descending }
        })
        .collect()
}

fn cmp_decorated(keys: &[SortKeyCol], a: usize, b: usize) -> Ordering {
    for k in keys {
        let na = k.valid.map_or(false, |v| !v[a]);
        let nb = k.valid.map_or(false, |v| !v[b]);
        // NULLS LAST in ascending order.
        let ord = match (na, nb) {
            (true, true) => Ordering::Equal,
            (true, false) => Ordering::Greater,
            (false, true) => Ordering::Less,
            (false, false) => match &k.vals {
                SortVals::I64(d) => d[a].cmp(&d[b]),
                SortVals::F64(d) => d[a].partial_cmp(&d[b]).unwrap_or(Ordering::Equal),
                SortVals::Str(d) => d[a].cmp(&d[b]),
                SortVals::Bool(d) => d[a].cmp(&d[b]),
            },
        };
        let ord = if k.descending { ord.reverse() } else { ord };
        if ord != Ordering::Equal {
            return ord;
        }
    }
    Ordering::Equal
}

/// Legacy comparator over scalar `Value`s (row-at-a-time path).
fn cmp_values(keys: &[OrderKey], cols: &[Column], a: usize, b: usize) -> Ordering {
    for (k, col) in keys.iter().zip(cols) {
        let va = col.value(a);
        let vb = col.value(b);
        // NULLS LAST in ascending order.
        let ord = match (va.is_null(), vb.is_null()) {
            (true, true) => Ordering::Equal,
            (true, false) => Ordering::Greater,
            (false, true) => Ordering::Less,
            (false, false) => va.sql_cmp(&vb).unwrap_or(Ordering::Equal),
        };
        let ord = if k.descending { ord.reverse() } else { ord };
        if ord != Ordering::Equal {
            return ord;
        }
    }
    Ordering::Equal
}

/// Order `idx` by `cmp`; with a limit, partition the top `k` first
/// (`select_nth_unstable_by`) and only sort that prefix.
fn apply_order<F: FnMut(&usize, &usize) -> Ordering>(
    idx: &mut Vec<usize>,
    limit: Option<usize>,
    cmp: &mut F,
) {
    match limit {
        Some(0) => idx.clear(),
        Some(k) if k < idx.len() => {
            let _ = idx.select_nth_unstable_by(k - 1, &mut *cmp);
            idx[..k].sort_unstable_by(&mut *cmp);
            idx.truncate(k);
        }
        _ => idx.sort_unstable_by(&mut *cmp),
    }
}

/// Sort (optionally top-k when `limit` is set). Sort keys are decorated
/// once — typed slices + validity — instead of materializing two `Value`s
/// per comparison. The comparator is a strict total order (index
/// tiebreak), so top-k output is identical to sort-then-limit.
fn sort(
    rows: &RowSet,
    keys: &[OrderKey],
    ctx: &ExecContext,
    limit: Option<usize>,
) -> Result<RowSet> {
    let key_cols: Vec<Column> = keys
        .iter()
        .map(|k| eval(&k.expr, rows, ctx))
        .collect::<Result<_>>()?;
    let mut idx: Vec<usize> = (0..rows.num_rows()).collect();
    if ctx.vectorized {
        let dk = decorate(keys, &key_cols);
        let mut cmp =
            |a: &usize, b: &usize| cmp_decorated(&dk, *a, *b).then_with(|| a.cmp(b));
        apply_order(&mut idx, limit, &mut cmp);
    } else {
        let mut cmp =
            |a: &usize, b: &usize| cmp_values(keys, &key_cols, *a, *b).then_with(|| a.cmp(b));
        apply_order(&mut idx, limit, &mut cmp);
    }
    Ok(rows.take(&idx))
}

/// Convenience: parse, plan, and execute a SQL string.
pub fn run_sql(sql: &str, ctx: &ExecContext) -> Result<RowSet> {
    Ok(run_sql_with_stats(sql, ctx)?.0)
}

/// Like [`run_sql`], also returning per-operator rows and timings.
pub fn run_sql_with_stats(sql: &str, ctx: &ExecContext) -> Result<(RowSet, QueryStats)> {
    let q = crate::sql::parse_query(sql)?;
    let plan = super::plan::plan_query(&q, &ctx.udfs)?;
    execute_plan_with_stats(&plan, ctx)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> ExecContext {
        let catalog = Arc::new(Catalog::new());
        let sales = RowSet::new(
            Schema::new(vec![
                Field::new("id", DataType::Int64),
                Field::new("cat", DataType::Utf8),
                Field::new("price", DataType::Float64),
                Field::new("qty", DataType::Int64),
            ]),
            vec![
                Column::from_i64(vec![1, 2, 3, 4, 5]),
                Column::from_strings(
                    ["a", "b", "a", "b", "a"].iter().map(|s| s.to_string()).collect(),
                ),
                Column::from_f64(vec![10.0, 20.0, 30.0, 40.0, 50.0]),
                Column::from_i64(vec![1, 2, 3, 4, 5]),
            ],
        )
        .unwrap();
        catalog.register("sales", sales);
        let cats = RowSet::new(
            Schema::new(vec![
                Field::new("cat", DataType::Utf8),
                Field::new("label", DataType::Utf8),
            ]),
            vec![
                Column::from_strings(vec!["a".into(), "c".into()]),
                Column::from_strings(vec!["alpha".into(), "gamma".into()]),
            ],
        )
        .unwrap();
        catalog.register("cats", cats);
        ExecContext::new(catalog, Arc::new(UdfRegistry::new()))
    }

    fn sql(s: &str) -> RowSet {
        run_sql(s, &ctx()).unwrap_or_else(|e| panic!("{s}: {e}"))
    }

    /// Same statement through the codec and the legacy row path.
    fn sql_both(s: &str) -> (RowSet, RowSet) {
        let vectorized = run_sql(s, &ctx()).unwrap_or_else(|e| panic!("{s}: {e}"));
        let rowwise = run_sql(s, &ctx().with_vectorized(false))
            .unwrap_or_else(|e| panic!("{s} (rowwise): {e}"));
        (vectorized, rowwise)
    }

    #[test]
    fn scan_filter_project() {
        let rs = sql("SELECT id, price * qty AS total FROM sales WHERE price > 15");
        assert_eq!(rs.num_rows(), 4);
        assert_eq!(rs.schema.names(), vec!["id", "total"]);
        assert_eq!(rs.row(0), vec![Value::Int(2), Value::Float(40.0)]);
    }

    #[test]
    fn select_star() {
        let rs = sql("SELECT * FROM sales LIMIT 2");
        assert_eq!(rs.num_rows(), 2);
        assert_eq!(rs.num_columns(), 4);
    }

    #[test]
    fn group_by_and_having() {
        let rs = sql(
            "SELECT cat, COUNT(*) AS n, SUM(price) AS total, AVG(qty) AS avg_q \
             FROM sales GROUP BY cat ORDER BY cat",
        );
        assert_eq!(rs.num_rows(), 2);
        assert_eq!(
            rs.row(0),
            vec![
                Value::Str("a".into()),
                Value::Int(3),
                Value::Float(90.0),
                Value::Float(3.0)
            ]
        );
        let rs = sql("SELECT cat FROM sales GROUP BY cat HAVING SUM(price) > 80 ORDER BY cat");
        assert_eq!(rs.num_rows(), 1);
        assert_eq!(rs.row(0)[0], Value::Str("a".into()));
    }

    #[test]
    fn global_aggregate_empty_input() {
        let rs = sql("SELECT COUNT(*) AS n, SUM(price) AS s FROM sales WHERE price > 999");
        assert_eq!(rs.num_rows(), 1);
        assert_eq!(rs.row(0), vec![Value::Int(0), Value::Null]);
    }

    #[test]
    fn min_max_and_expression_aggregates() {
        let rs = sql("SELECT MIN(price) AS lo, MAX(price * qty) AS hi FROM sales");
        assert_eq!(rs.row(0), vec![Value::Float(10.0), Value::Float(250.0)]);
    }

    #[test]
    fn inner_join() {
        let rs = sql(
            "SELECT s.id, c.label FROM sales s JOIN cats c ON s.cat = c.cat ORDER BY s.id",
        );
        assert_eq!(rs.num_rows(), 3); // only cat 'a' matches
        assert_eq!(rs.row(0), vec![Value::Int(1), Value::Str("alpha".into())]);
    }

    #[test]
    fn left_join_preserves_unmatched() {
        let rs = sql(
            "SELECT s.id, c.label FROM sales s LEFT JOIN cats c ON s.cat = c.cat ORDER BY s.id",
        );
        assert_eq!(rs.num_rows(), 5);
        assert_eq!(rs.row(1), vec![Value::Int(2), Value::Null]); // cat 'b'
    }

    #[test]
    fn join_with_residual() {
        let rs = sql(
            "SELECT s.id FROM sales s JOIN cats c ON s.cat = c.cat AND s.price > 25 ORDER BY s.id",
        );
        assert_eq!(rs.num_rows(), 2); // ids 3, 5
    }

    #[test]
    fn colliding_join_columns_get_qualified() {
        let rs = sql("SELECT s.cat, c.cat FROM sales s JOIN cats c ON s.cat = c.cat LIMIT 1");
        assert_eq!(rs.num_columns(), 2);
    }

    #[test]
    fn order_by_desc_and_nulls() {
        let rs = sql("SELECT id FROM sales ORDER BY price DESC LIMIT 2");
        assert_eq!(rs.row(0)[0], Value::Int(5));
        assert_eq!(rs.row(1)[0], Value::Int(4));
    }

    #[test]
    fn order_by_alias() {
        let rs = sql("SELECT id, price * qty AS total FROM sales ORDER BY total DESC LIMIT 1");
        assert_eq!(rs.row(0)[0], Value::Int(5));
    }

    #[test]
    fn subquery_pipeline() {
        let rs = sql(
            "SELECT cat, n FROM (SELECT cat, COUNT(*) AS n FROM sales GROUP BY cat) t \
             WHERE n > 2",
        );
        assert_eq!(rs.num_rows(), 1);
        assert_eq!(rs.row(0)[0], Value::Str("a".into()));
    }

    #[test]
    fn select_without_from() {
        let rs = sql("SELECT 1 + 1 AS two");
        assert_eq!(rs.num_rows(), 1);
        assert_eq!(rs.row(0)[0], Value::Int(2));
    }

    #[test]
    fn case_in_group_by() {
        let rs = sql(
            "SELECT CASE WHEN price > 25 THEN 'hi' ELSE 'lo' END AS band, COUNT(*) AS n \
             FROM sales GROUP BY CASE WHEN price > 25 THEN 'hi' ELSE 'lo' END ORDER BY band",
        );
        assert_eq!(rs.num_rows(), 2);
        assert_eq!(rs.row(0), vec![Value::Str("hi".into()), Value::Int(3)]);
    }

    #[test]
    fn limit_zero_and_overrun() {
        assert_eq!(sql("SELECT * FROM sales LIMIT 0").num_rows(), 0);
        assert_eq!(sql("SELECT * FROM sales LIMIT 99").num_rows(), 5);
    }

    #[test]
    fn codec_and_rowwise_paths_agree() {
        for q in [
            "SELECT cat, COUNT(*) AS n, SUM(price) AS s, AVG(qty) AS a, MIN(price) AS lo, \
             MAX(price) AS hi FROM sales GROUP BY cat",
            "SELECT qty, COUNT(*) AS n FROM sales GROUP BY qty",
            "SELECT s.id, c.label FROM sales s JOIN cats c ON s.cat = c.cat",
            "SELECT s.id, c.label FROM sales s LEFT JOIN cats c ON s.cat = c.cat",
            "SELECT id, cat FROM sales ORDER BY cat, price DESC",
            "SELECT id FROM sales ORDER BY price DESC LIMIT 3",
        ] {
            let (vectorized, rowwise) = sql_both(q);
            assert_eq!(vectorized, rowwise, "{q}");
        }
    }

    #[test]
    fn sum_int_keeps_i64_precision() {
        // 2^53 + 1 is not representable in f64: the old f64 accumulator
        // silently rounded it.
        let catalog = Arc::new(Catalog::new());
        let big = (1i64 << 53) + 1;
        let t = RowSet::new(
            Schema::new(vec![Field::new("x", DataType::Int64)]),
            vec![Column::from_i64(vec![big, 0])],
        )
        .unwrap();
        catalog.register("t", t);
        for vectorized in [true, false] {
            let c = ExecContext::new(catalog.clone(), Arc::new(UdfRegistry::new()))
                .with_vectorized(vectorized);
            let rs = run_sql("SELECT SUM(x) AS s FROM t", &c).unwrap();
            assert_eq!(rs.row(0)[0], Value::Int(big), "vectorized={vectorized}");
        }
    }

    #[test]
    fn sum_int_overflow_widens_to_float() {
        let catalog = Arc::new(Catalog::new());
        let t = RowSet::new(
            Schema::new(vec![Field::new("x", DataType::Int64)]),
            vec![Column::from_i64(vec![i64::MAX, i64::MAX])],
        )
        .unwrap();
        catalog.register("t", t);
        for vectorized in [true, false] {
            let c = ExecContext::new(catalog.clone(), Arc::new(UdfRegistry::new()))
                .with_vectorized(vectorized);
            let rs = run_sql("SELECT SUM(x) AS s FROM t", &c).unwrap();
            let got = rs.row(0)[0].as_f64().unwrap();
            let want = i64::MAX as f64 * 2.0;
            assert!((got - want).abs() / want < 1e-12, "vectorized={vectorized}: {got}");
        }
    }

    #[test]
    fn top_k_matches_full_sort() {
        let rs_k = sql("SELECT id FROM sales ORDER BY price DESC, id LIMIT 2");
        assert_eq!(rs_k.num_rows(), 2);
        assert_eq!(rs_k.row(0)[0], Value::Int(5));
        assert_eq!(rs_k.row(1)[0], Value::Int(4));
        // Hidden sort key (ORDER BY column not in the select list) also
        // takes the top-k path through the planner's projection.
        let rs_h = sql("SELECT cat FROM sales ORDER BY price DESC LIMIT 1");
        assert_eq!(rs_h.row(0)[0], Value::Str("a".into()));
        assert_eq!(rs_h.schema.names(), vec!["cat"]);
    }

    #[test]
    fn query_stats_observe_operators() {
        let (out, stats) =
            run_sql_with_stats("SELECT cat, COUNT(*) AS n FROM sales GROUP BY cat", &ctx())
                .unwrap();
        assert_eq!(stats.rows_scanned, 5);
        assert_eq!(stats.rows_output, out.num_rows() as u64);
        assert_eq!(stats.aggregate.invocations, 1);
        assert_eq!(stats.aggregate.rows_in, 5);
        assert_eq!(stats.aggregate.rows_out, 2);
        let report = stats.report();
        assert!(report.contains("aggregate"), "{report}");
    }

    #[test]
    fn scalar_udf_in_query() {
        let c = ctx();
        let mut udfs = UdfRegistry::new();
        udfs.register_scalar(
            "add_tax",
            DataType::Float64,
            Arc::new(|args| {
                Ok(Value::Float(args[0].as_f64().unwrap_or(0.0) * 1.1))
            }),
        );
        let c = ExecContext::new(c.catalog, Arc::new(udfs));
        let rs = run_sql("SELECT add_tax(price) AS p FROM sales WHERE id = 1", &c).unwrap();
        assert_eq!(rs.row(0)[0], Value::Float(11.0));
    }

    #[test]
    fn udaf_in_query() {
        let c = ctx();
        let mut udfs = UdfRegistry::new();
        // Geometric-mean UDAF.
        struct Geo {
            log_sum: f64,
            n: i64,
        }
        impl crate::udf::UdafState for Geo {
            fn update(&mut self, args: &[Value]) -> Result<()> {
                if let Some(x) = args[0].as_f64() {
                    if x > 0.0 {
                        self.log_sum += x.ln();
                        self.n += 1;
                    }
                }
                Ok(())
            }
            fn merge(&mut self, other: Box<dyn crate::udf::UdafState>) -> Result<()> {
                let o = other.as_any().downcast_ref::<Geo>().unwrap();
                self.log_sum += o.log_sum;
                self.n += o.n;
                Ok(())
            }
            fn finish(&self) -> Result<Value> {
                if self.n == 0 {
                    Ok(Value::Null)
                } else {
                    Ok(Value::Float((self.log_sum / self.n as f64).exp()))
                }
            }
            fn as_any(&self) -> &dyn std::any::Any {
                self
            }
        }
        udfs.register_udaf(
            "geomean",
            DataType::Float64,
            Arc::new(|| Box::new(Geo { log_sum: 0.0, n: 0 })),
        );
        let c = ExecContext::new(c.catalog, Arc::new(udfs));
        let rs = run_sql("SELECT geomean(price) AS g FROM sales", &c).unwrap();
        let g = rs.row(0)[0].as_f64().unwrap();
        let want = (10f64 * 20.0 * 30.0 * 40.0 * 50.0).powf(0.2);
        assert!((g - want).abs() < 1e-9, "{g} vs {want}");
    }
}
