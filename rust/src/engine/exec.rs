//! Plan execution: vectorized operators over rowsets.

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::sql::ast::{Expr, JoinKind, OrderKey};
use crate::types::{Column, DataType, Field, RowSet, Schema, Value};
use crate::udf::{UdfRegistry, UdfStatsStore};

use super::catalog::Catalog;
use super::expr::{eval_expr, eval_predicate, eval_row, resolve_column};
use super::key::KeyValue;
use super::plan::{AggCall, AggFunc, Plan};

/// Everything an operator needs at execution time.
pub struct ExecContext {
    pub catalog: Arc<Catalog>,
    pub udfs: Arc<UdfRegistry>,
    pub udf_stats: Arc<UdfStatsStore>,
}

impl ExecContext {
    pub fn new(catalog: Arc<Catalog>, udfs: Arc<UdfRegistry>) -> Self {
        Self { catalog, udfs, udf_stats: Arc::new(UdfStatsStore::new()) }
    }
}

/// Per-query execution statistics (rows processed per operator class).
#[derive(Debug, Default, Clone)]
pub struct QueryStats {
    pub rows_scanned: u64,
    pub rows_output: u64,
}

/// Execute a plan to completion.
pub fn execute_plan(plan: &Plan, ctx: &ExecContext) -> Result<RowSet> {
    let mut stats = QueryStats::default();
    let out = exec(plan, ctx, &mut stats)?;
    Ok(out)
}

fn exec(plan: &Plan, ctx: &ExecContext, stats: &mut QueryStats) -> Result<RowSet> {
    match plan {
        Plan::Scan { table, alias: _ } => {
            let rs = ctx.catalog.get(table)?;
            stats.rows_scanned += rs.num_rows() as u64;
            Ok(rs)
        }
        Plan::TableFunc { name, args, alias: _ } => {
            if name == "__dual" {
                // SELECT without FROM: one row, zero columns.
                return Ok(RowSet::new(
                    Schema::new(vec![Field::new("__dummy", DataType::Int64)]),
                    vec![Column::from_i64(vec![0])],
                )
                .unwrap());
            }
            // Evaluate constant args against a dual row.
            let dual = RowSet::new(
                Schema::new(vec![Field::new("__dummy", DataType::Int64)]),
                vec![Column::from_i64(vec![0])],
            )
            .unwrap();
            let arg_vals: Vec<Value> = args
                .iter()
                .map(|a| eval_row(a, &dual, 0, &ctx.udfs))
                .collect::<Result<_>>()?;
            ctx.catalog
                .get(name)
                .or_else(|_| ctx.udfs.call_udtf(name, &arg_vals))
        }
        Plan::Filter { input, predicate } => {
            let rows = exec(input, ctx, stats)?;
            let mask = eval_predicate(predicate, &rows, &ctx.udfs)?;
            Ok(rows.filter(&mask))
        }
        Plan::Project { input, exprs } => {
            let rows = exec(input, ctx, stats)?;
            project(&rows, exprs, ctx)
        }
        Plan::Aggregate { input, group, aggs } => {
            let rows = exec(input, ctx, stats)?;
            aggregate(&rows, group, aggs, ctx)
        }
        Plan::Join { left, right, kind, equi, residual } => {
            let l = exec(left, ctx, stats)?;
            let r = exec(right, ctx, stats)?;
            join(&l, &r, *kind, equi, residual.as_ref(), ctx, plan)
        }
        Plan::Sort { input, keys } => {
            let rows = exec(input, ctx, stats)?;
            sort(&rows, keys, ctx)
        }
        Plan::Limit { input, n } => {
            let rows = exec(input, ctx, stats)?;
            Ok(rows.slice(0, (*n).min(rows.num_rows())))
        }
    }
}

fn project(rows: &RowSet, exprs: &[(Expr, String)], ctx: &ExecContext) -> Result<RowSet> {
    let mut fields = Vec::new();
    let mut columns = Vec::new();
    for (e, name) in exprs {
        // Marker from the planner: keep everything except hidden sort keys.
        if matches!(e, Expr::Func { name, .. } if name == "__drop_hidden") {
            for (f, c) in rows.schema.fields.iter().zip(&rows.columns) {
                if !f.name.starts_with("__sort_") {
                    fields.push(f.clone());
                    columns.push(c.clone());
                }
            }
            continue;
        }
        if matches!(e, Expr::Star) {
            // Wildcard expansion mixed with other expressions.
            for (f, c) in rows.schema.fields.iter().zip(&rows.columns) {
                fields.push(f.clone());
                columns.push(c.clone());
            }
            continue;
        }
        let col = eval_expr(e, rows, &ctx.udfs)?;
        fields.push(Field::new(name.clone(), col.data_type()));
        columns.push(col);
    }
    RowSet::new(Schema::new(fields), columns)
}

// ---------------------------------------------------------------- aggregate

struct GroupState {
    key_row: Vec<Value>,
    accs: Vec<AggAcc>,
}

enum AggAcc {
    CountStar(i64),
    Count(i64),
    Sum { sum: f64, all_int: bool, any: bool },
    Avg { sum: f64, n: i64 },
    Min(Option<Value>),
    Max(Option<Value>),
    Udaf(Box<dyn crate::udf::UdafState>),
}

impl AggAcc {
    fn new(call: &AggCall, udfs: &UdfRegistry) -> Result<AggAcc> {
        Ok(match call.func {
            AggFunc::CountStar => AggAcc::CountStar(0),
            AggFunc::Count => AggAcc::Count(0),
            AggFunc::Sum => AggAcc::Sum { sum: 0.0, all_int: true, any: false },
            AggFunc::Avg => AggAcc::Avg { sum: 0.0, n: 0 },
            AggFunc::Min => AggAcc::Min(None),
            AggFunc::Max => AggAcc::Max(None),
            AggFunc::Udaf => {
                let udaf = udfs
                    .udaf(&call.name)
                    .ok_or_else(|| anyhow!("no UDAF {:?}", call.name))?;
                AggAcc::Udaf((udaf.factory)())
            }
        })
    }

    fn update(&mut self, args: &[Value]) -> Result<()> {
        match self {
            AggAcc::CountStar(n) => *n += 1,
            AggAcc::Count(n) => {
                if !args[0].is_null() {
                    *n += 1;
                }
            }
            AggAcc::Sum { sum, all_int, any } => {
                if !args[0].is_null() {
                    let v = args[0]
                        .as_f64()
                        .ok_or_else(|| anyhow!("SUM over non-numeric {}", args[0]))?;
                    if !matches!(args[0], Value::Int(_)) {
                        *all_int = false;
                    }
                    *sum += v;
                    *any = true;
                }
            }
            AggAcc::Avg { sum, n } => {
                if !args[0].is_null() {
                    *sum += args[0]
                        .as_f64()
                        .ok_or_else(|| anyhow!("AVG over non-numeric {}", args[0]))?;
                    *n += 1;
                }
            }
            AggAcc::Min(cur) => {
                if !args[0].is_null() {
                    let replace = match cur {
                        None => true,
                        Some(c) => {
                            args[0].sql_cmp(c) == Some(std::cmp::Ordering::Less)
                        }
                    };
                    if replace {
                        *cur = Some(args[0].clone());
                    }
                }
            }
            AggAcc::Max(cur) => {
                if !args[0].is_null() {
                    let replace = match cur {
                        None => true,
                        Some(c) => {
                            args[0].sql_cmp(c) == Some(std::cmp::Ordering::Greater)
                        }
                    };
                    if replace {
                        *cur = Some(args[0].clone());
                    }
                }
            }
            AggAcc::Udaf(state) => state.update(args)?,
        }
        Ok(())
    }

    fn finish(&self) -> Result<Value> {
        Ok(match self {
            AggAcc::CountStar(n) | AggAcc::Count(n) => Value::Int(*n),
            AggAcc::Sum { sum, all_int, any } => {
                if !any {
                    Value::Null
                } else if *all_int {
                    Value::Int(*sum as i64)
                } else {
                    Value::Float(*sum)
                }
            }
            AggAcc::Avg { sum, n } => {
                if *n == 0 {
                    Value::Null
                } else {
                    Value::Float(sum / *n as f64)
                }
            }
            AggAcc::Min(v) | AggAcc::Max(v) => v.clone().unwrap_or(Value::Null),
            AggAcc::Udaf(state) => state.finish()?,
        })
    }
}

fn aggregate(
    rows: &RowSet,
    group: &[(Expr, String)],
    aggs: &[AggCall],
    ctx: &ExecContext,
) -> Result<RowSet> {
    // Evaluate group keys and aggregate arguments as columns first
    // (vectorized), then fold rows into group states.
    let key_cols: Vec<Column> = group
        .iter()
        .map(|(e, _)| eval_expr(e, rows, &ctx.udfs))
        .collect::<Result<_>>()?;
    let arg_cols: Vec<Vec<Column>> = aggs
        .iter()
        .map(|a| {
            a.args
                .iter()
                .map(|e| eval_expr(e, rows, &ctx.udfs))
                .collect::<Result<Vec<_>>>()
        })
        .collect::<Result<_>>()?;

    let n = rows.num_rows();
    let mut groups: std::collections::HashMap<Vec<KeyValue>, GroupState> =
        std::collections::HashMap::new();
    // Preserve first-seen group order for deterministic output.
    let mut order: Vec<Vec<KeyValue>> = Vec::new();

    for r in 0..n {
        let key: Vec<KeyValue> = key_cols
            .iter()
            .map(|c| KeyValue::from_value(&c.value(r)))
            .collect();
        let state = match groups.get_mut(&key) {
            Some(s) => s,
            None => {
                let accs = aggs
                    .iter()
                    .map(|a| AggAcc::new(a, &ctx.udfs))
                    .collect::<Result<Vec<_>>>()?;
                let key_row = key_cols.iter().map(|c| c.value(r)).collect();
                order.push(key.clone());
                groups.insert(key.clone(), GroupState { key_row, accs });
                groups.get_mut(&key).unwrap()
            }
        };
        for (acc, cols) in state.accs.iter_mut().zip(&arg_cols) {
            let args: Vec<Value> = cols.iter().map(|c| c.value(r)).collect();
            acc.update(&args)?;
        }
    }

    // Global aggregation over empty input still yields one row.
    if group.is_empty() && groups.is_empty() {
        let accs = aggs
            .iter()
            .map(|a| AggAcc::new(a, &ctx.udfs))
            .collect::<Result<Vec<_>>>()?;
        order.push(vec![]);
        groups.insert(vec![], GroupState { key_row: vec![], accs });
    }

    // Materialize output.
    let mut out_values: Vec<Vec<Value>> = Vec::with_capacity(order.len());
    for key in &order {
        let state = &groups[key];
        let mut row = state.key_row.clone();
        for acc in &state.accs {
            row.push(acc.finish()?);
        }
        out_values.push(row);
    }
    let mut fields = Vec::new();
    for ((e, name), col) in group.iter().zip(&key_cols) {
        let _ = e;
        fields.push(Field::new(name.clone(), col.data_type()));
    }
    for a in aggs {
        let dt = match a.func {
            AggFunc::CountStar | AggFunc::Count => DataType::Int64,
            AggFunc::Avg => DataType::Float64,
            AggFunc::Sum | AggFunc::Min | AggFunc::Max => {
                // Derive from produced values; default Float64.
                out_values
                    .iter()
                    .find_map(|row| row[group.len() + aggs.iter().position(|x| std::ptr::eq(x, a)).unwrap()].data_type())
                    .unwrap_or(DataType::Float64)
            }
            AggFunc::Udaf => ctx
                .udfs
                .udaf(&a.name)
                .map(|u| u.return_type)
                .unwrap_or(DataType::Float64),
        };
        fields.push(Field::new(a.out_name.clone(), dt));
    }
    let schema = Schema::new(fields);
    let n_cols = schema.len();
    let mut columns = Vec::with_capacity(n_cols);
    for c in 0..n_cols {
        let vals: Vec<Value> = out_values.iter().map(|r| r[c].clone()).collect();
        // Widen Int to Float if mixed (e.g. SUM over mixed groups).
        let dt = if schema.field(c).data_type == DataType::Int64
            && vals.iter().any(|v| matches!(v, Value::Float(_)))
        {
            DataType::Float64
        } else {
            schema.field(c).data_type
        };
        columns.push(Column::from_values(dt, &vals)?);
    }
    let fields = schema
        .fields
        .iter()
        .zip(&columns)
        .map(|(f, c)| Field::new(f.name.clone(), c.data_type()))
        .collect();
    RowSet::new(Schema::new(fields), columns)
}

// --------------------------------------------------------------------- join

/// Build the combined schema for a join, qualifying colliding names.
fn join_schema(l: &RowSet, lalias: &str, r: &RowSet, ralias: &str) -> Schema {
    let mut fields = Vec::new();
    let collides = |name: &str| {
        l.schema.index_of(name).is_some() && r.schema.index_of(name).is_some()
    };
    for f in &l.schema.fields {
        let name = if collides(&f.name) {
            format!("{lalias}.{}", f.name)
        } else {
            f.name.clone()
        };
        fields.push(Field::new(name, f.data_type));
    }
    for f in &r.schema.fields {
        let name = if collides(&f.name) {
            format!("{ralias}.{}", f.name)
        } else {
            f.name.clone()
        };
        fields.push(Field::new(name, f.data_type));
    }
    Schema::new(fields)
}

fn plan_alias(p: &Plan, default: &str) -> String {
    match p {
        Plan::Scan { table, alias } => alias.clone().unwrap_or_else(|| table.clone()),
        Plan::TableFunc { name, alias, .. } => alias.clone().unwrap_or_else(|| name.clone()),
        Plan::Filter { input, .. } | Plan::Limit { input, .. } | Plan::Sort { input, .. } => {
            plan_alias(input, default)
        }
        _ => default.to_string(),
    }
}

/// Hash join (equi) with optional residual filter; falls back to a
/// nested-loop cross product + filter when no equi keys exist.
fn join(
    l: &RowSet,
    r: &RowSet,
    kind: JoinKind,
    equi: &[(Expr, Expr)],
    residual: Option<&Expr>,
    ctx: &ExecContext,
    plan: &Plan,
) -> Result<RowSet> {
    let (lalias, ralias) = match plan {
        Plan::Join { left, right, .. } => {
            (plan_alias(left, "l"), plan_alias(right, "r"))
        }
        _ => ("l".to_string(), "r".to_string()),
    };
    let out_schema = join_schema(l, &lalias, r, &ralias);

    // Assign each equi pair's sides: an expression belongs to the side
    // whose schema resolves all its columns.
    let resolvable = |e: &Expr, rs: &RowSet| -> bool {
        let mut cols = Vec::new();
        e.referenced_columns(&mut cols);
        !cols.is_empty() && cols.iter().all(|c| resolve_column(&rs.schema, c).is_ok())
    };
    let mut lkeys: Vec<&Expr> = Vec::new();
    let mut rkeys: Vec<&Expr> = Vec::new();
    for (a, b) in equi {
        if resolvable(a, l) && resolvable(b, r) {
            lkeys.push(a);
            rkeys.push(b);
        } else if resolvable(b, l) && resolvable(a, r) {
            lkeys.push(b);
            rkeys.push(a);
        } else {
            bail!(
                "cannot assign join condition {} = {} to sides",
                a.to_sql(),
                b.to_sql()
            );
        }
    }

    let mut l_idx: Vec<usize> = Vec::new();
    let mut r_idx: Vec<i64> = Vec::new(); // -1 = NULL row (left join)

    if lkeys.is_empty() {
        // Cross product (small inputs only — residual filters after).
        for i in 0..l.num_rows() {
            let mut matched = false;
            for j in 0..r.num_rows() {
                l_idx.push(i);
                r_idx.push(j as i64);
                matched = true;
            }
            if !matched && kind == JoinKind::Left {
                l_idx.push(i);
                r_idx.push(-1);
            }
        }
    } else {
        // Build hash table on the right side.
        let rkey_cols: Vec<Column> = rkeys
            .iter()
            .map(|e| eval_expr(e, r, &ctx.udfs))
            .collect::<Result<_>>()?;
        let mut table: std::collections::HashMap<Vec<KeyValue>, Vec<usize>> =
            std::collections::HashMap::new();
        for j in 0..r.num_rows() {
            let key: Vec<KeyValue> = rkey_cols
                .iter()
                .map(|c| KeyValue::join_normalized(&c.value(j)))
                .collect();
            // SQL join: NULL keys never match.
            if key.iter().any(|k| matches!(k, KeyValue::Null)) {
                continue;
            }
            table.entry(key).or_default().push(j);
        }
        let lkey_cols: Vec<Column> = lkeys
            .iter()
            .map(|e| eval_expr(e, l, &ctx.udfs))
            .collect::<Result<_>>()?;
        for i in 0..l.num_rows() {
            let key: Vec<KeyValue> = lkey_cols
                .iter()
                .map(|c| KeyValue::join_normalized(&c.value(i)))
                .collect();
            let matches = if key.iter().any(|k| matches!(k, KeyValue::Null)) {
                None
            } else {
                table.get(&key)
            };
            match matches {
                Some(js) => {
                    for &j in js {
                        l_idx.push(i);
                        r_idx.push(j as i64);
                    }
                }
                None => {
                    if kind == JoinKind::Left {
                        l_idx.push(i);
                        r_idx.push(-1);
                    }
                }
            }
        }
    }

    // Materialize the combined rowset.
    let combined = materialize_join(l, r, &out_schema, &l_idx, &r_idx)?;

    // Residual predicate + left-join NULL-row preservation: rows that fail
    // the residual are dropped (inner) or, for left joins where every match
    // fails, the engine would need to re-emit a NULL row. This engine
    // applies residuals before NULL-row synthesis only for inner joins and
    // documents the left-join limitation.
    let combined = match residual {
        Some(pred) => {
            let mask = eval_predicate(pred, &combined, &ctx.udfs)?;
            combined.filter(&mask)
        }
        None => combined,
    };
    Ok(combined)
}

fn materialize_join(
    l: &RowSet,
    r: &RowSet,
    schema: &Schema,
    l_idx: &[usize],
    r_idx: &[i64],
) -> Result<RowSet> {
    let left_cols = l.num_columns();
    let mut columns = Vec::with_capacity(schema.len());
    for (c, f) in schema.fields.iter().enumerate() {
        if c < left_cols {
            columns.push(l.column(c).take(l_idx));
        } else {
            let src = r.column(c - left_cols);
            // Gather with NULLs for -1 (unmatched left rows).
            let values: Vec<Value> = r_idx
                .iter()
                .map(|&j| {
                    if j < 0 {
                        Value::Null
                    } else {
                        src.value(j as usize)
                    }
                })
                .collect();
            columns.push(Column::from_values(f.data_type, &values)?);
        }
    }
    RowSet::new(schema.clone(), columns)
}

// --------------------------------------------------------------------- sort

fn sort(rows: &RowSet, keys: &[OrderKey], ctx: &ExecContext) -> Result<RowSet> {
    let key_cols: Vec<Column> = keys
        .iter()
        .map(|k| eval_expr(&k.expr, rows, &ctx.udfs))
        .collect::<Result<_>>()?;
    let mut idx: Vec<usize> = (0..rows.num_rows()).collect();
    idx.sort_by(|&a, &b| {
        for (k, col) in keys.iter().zip(&key_cols) {
            let va = col.value(a);
            let vb = col.value(b);
            // NULLS LAST in ascending order.
            let ord = match (va.is_null(), vb.is_null()) {
                (true, true) => std::cmp::Ordering::Equal,
                (true, false) => std::cmp::Ordering::Greater,
                (false, true) => std::cmp::Ordering::Less,
                (false, false) => va.sql_cmp(&vb).unwrap_or(std::cmp::Ordering::Equal),
            };
            let ord = if k.descending { ord.reverse() } else { ord };
            if ord != std::cmp::Ordering::Equal {
                return ord;
            }
        }
        a.cmp(&b) // stable tiebreak
    });
    Ok(rows.take(&idx))
}

/// Convenience: parse, plan, and execute a SQL string.
pub fn run_sql(sql: &str, ctx: &ExecContext) -> Result<RowSet> {
    let q = crate::sql::parse_query(sql)?;
    let plan = super::plan::plan_query(&q, &ctx.udfs)?;
    execute_plan(&plan, ctx)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> ExecContext {
        let catalog = Arc::new(Catalog::new());
        let sales = RowSet::new(
            Schema::new(vec![
                Field::new("id", DataType::Int64),
                Field::new("cat", DataType::Utf8),
                Field::new("price", DataType::Float64),
                Field::new("qty", DataType::Int64),
            ]),
            vec![
                Column::from_i64(vec![1, 2, 3, 4, 5]),
                Column::from_strings(
                    ["a", "b", "a", "b", "a"].iter().map(|s| s.to_string()).collect(),
                ),
                Column::from_f64(vec![10.0, 20.0, 30.0, 40.0, 50.0]),
                Column::from_i64(vec![1, 2, 3, 4, 5]),
            ],
        )
        .unwrap();
        catalog.register("sales", sales);
        let cats = RowSet::new(
            Schema::new(vec![
                Field::new("cat", DataType::Utf8),
                Field::new("label", DataType::Utf8),
            ]),
            vec![
                Column::from_strings(vec!["a".into(), "c".into()]),
                Column::from_strings(vec!["alpha".into(), "gamma".into()]),
            ],
        )
        .unwrap();
        catalog.register("cats", cats);
        ExecContext::new(catalog, Arc::new(UdfRegistry::new()))
    }

    fn sql(s: &str) -> RowSet {
        run_sql(s, &ctx()).unwrap_or_else(|e| panic!("{s}: {e}"))
    }

    #[test]
    fn scan_filter_project() {
        let rs = sql("SELECT id, price * qty AS total FROM sales WHERE price > 15");
        assert_eq!(rs.num_rows(), 4);
        assert_eq!(rs.schema.names(), vec!["id", "total"]);
        assert_eq!(rs.row(0), vec![Value::Int(2), Value::Float(40.0)]);
    }

    #[test]
    fn select_star() {
        let rs = sql("SELECT * FROM sales LIMIT 2");
        assert_eq!(rs.num_rows(), 2);
        assert_eq!(rs.num_columns(), 4);
    }

    #[test]
    fn group_by_and_having() {
        let rs = sql(
            "SELECT cat, COUNT(*) AS n, SUM(price) AS total, AVG(qty) AS avg_q \
             FROM sales GROUP BY cat ORDER BY cat",
        );
        assert_eq!(rs.num_rows(), 2);
        assert_eq!(
            rs.row(0),
            vec![
                Value::Str("a".into()),
                Value::Int(3),
                Value::Float(90.0),
                Value::Float(3.0)
            ]
        );
        let rs = sql("SELECT cat FROM sales GROUP BY cat HAVING SUM(price) > 80 ORDER BY cat");
        assert_eq!(rs.num_rows(), 1);
        assert_eq!(rs.row(0)[0], Value::Str("a".into()));
    }

    #[test]
    fn global_aggregate_empty_input() {
        let rs = sql("SELECT COUNT(*) AS n, SUM(price) AS s FROM sales WHERE price > 999");
        assert_eq!(rs.num_rows(), 1);
        assert_eq!(rs.row(0), vec![Value::Int(0), Value::Null]);
    }

    #[test]
    fn min_max_and_expression_aggregates() {
        let rs = sql("SELECT MIN(price) AS lo, MAX(price * qty) AS hi FROM sales");
        assert_eq!(rs.row(0), vec![Value::Float(10.0), Value::Float(250.0)]);
    }

    #[test]
    fn inner_join() {
        let rs = sql(
            "SELECT s.id, c.label FROM sales s JOIN cats c ON s.cat = c.cat ORDER BY s.id",
        );
        assert_eq!(rs.num_rows(), 3); // only cat 'a' matches
        assert_eq!(rs.row(0), vec![Value::Int(1), Value::Str("alpha".into())]);
    }

    #[test]
    fn left_join_preserves_unmatched() {
        let rs = sql(
            "SELECT s.id, c.label FROM sales s LEFT JOIN cats c ON s.cat = c.cat ORDER BY s.id",
        );
        assert_eq!(rs.num_rows(), 5);
        assert_eq!(rs.row(1), vec![Value::Int(2), Value::Null]); // cat 'b'
    }

    #[test]
    fn join_with_residual() {
        let rs = sql(
            "SELECT s.id FROM sales s JOIN cats c ON s.cat = c.cat AND s.price > 25 ORDER BY s.id",
        );
        assert_eq!(rs.num_rows(), 2); // ids 3, 5
    }

    #[test]
    fn colliding_join_columns_get_qualified() {
        let rs = sql("SELECT s.cat, c.cat FROM sales s JOIN cats c ON s.cat = c.cat LIMIT 1");
        assert_eq!(rs.num_columns(), 2);
    }

    #[test]
    fn order_by_desc_and_nulls() {
        let rs = sql("SELECT id FROM sales ORDER BY price DESC LIMIT 2");
        assert_eq!(rs.row(0)[0], Value::Int(5));
        assert_eq!(rs.row(1)[0], Value::Int(4));
    }

    #[test]
    fn order_by_alias() {
        let rs = sql("SELECT id, price * qty AS total FROM sales ORDER BY total DESC LIMIT 1");
        assert_eq!(rs.row(0)[0], Value::Int(5));
    }

    #[test]
    fn subquery_pipeline() {
        let rs = sql(
            "SELECT cat, n FROM (SELECT cat, COUNT(*) AS n FROM sales GROUP BY cat) t \
             WHERE n > 2",
        );
        assert_eq!(rs.num_rows(), 1);
        assert_eq!(rs.row(0)[0], Value::Str("a".into()));
    }

    #[test]
    fn select_without_from() {
        let rs = sql("SELECT 1 + 1 AS two");
        assert_eq!(rs.num_rows(), 1);
        assert_eq!(rs.row(0)[0], Value::Int(2));
    }

    #[test]
    fn case_in_group_by() {
        let rs = sql(
            "SELECT CASE WHEN price > 25 THEN 'hi' ELSE 'lo' END AS band, COUNT(*) AS n \
             FROM sales GROUP BY CASE WHEN price > 25 THEN 'hi' ELSE 'lo' END ORDER BY band",
        );
        assert_eq!(rs.num_rows(), 2);
        assert_eq!(rs.row(0), vec![Value::Str("hi".into()), Value::Int(3)]);
    }

    #[test]
    fn limit_zero_and_overrun() {
        assert_eq!(sql("SELECT * FROM sales LIMIT 0").num_rows(), 0);
        assert_eq!(sql("SELECT * FROM sales LIMIT 99").num_rows(), 5);
    }

    #[test]
    fn scalar_udf_in_query() {
        let c = ctx();
        let mut udfs = UdfRegistry::new();
        udfs.register_scalar(
            "add_tax",
            DataType::Float64,
            Arc::new(|args| {
                Ok(Value::Float(args[0].as_f64().unwrap_or(0.0) * 1.1))
            }),
        );
        let c = ExecContext::new(c.catalog, Arc::new(udfs));
        let rs = run_sql("SELECT add_tax(price) AS p FROM sales WHERE id = 1", &c).unwrap();
        assert_eq!(rs.row(0)[0], Value::Float(11.0));
    }

    #[test]
    fn udaf_in_query() {
        let c = ctx();
        let mut udfs = UdfRegistry::new();
        // Geometric-mean UDAF.
        struct Geo {
            log_sum: f64,
            n: i64,
        }
        impl crate::udf::UdafState for Geo {
            fn update(&mut self, args: &[Value]) -> Result<()> {
                if let Some(x) = args[0].as_f64() {
                    if x > 0.0 {
                        self.log_sum += x.ln();
                        self.n += 1;
                    }
                }
                Ok(())
            }
            fn merge(&mut self, other: Box<dyn crate::udf::UdafState>) -> Result<()> {
                let o = other.as_any().downcast_ref::<Geo>().unwrap();
                self.log_sum += o.log_sum;
                self.n += o.n;
                Ok(())
            }
            fn finish(&self) -> Result<Value> {
                if self.n == 0 {
                    Ok(Value::Null)
                } else {
                    Ok(Value::Float((self.log_sum / self.n as f64).exp()))
                }
            }
            fn as_any(&self) -> &dyn std::any::Any {
                self
            }
        }
        udfs.register_udaf(
            "geomean",
            DataType::Float64,
            Arc::new(|| Box::new(Geo { log_sum: 0.0, n: 0 })),
        );
        let c = ExecContext::new(c.catalog, Arc::new(udfs));
        let rs = run_sql("SELECT geomean(price) AS g FROM sales", &c).unwrap();
        let g = rs.row(0)[0].as_f64().unwrap();
        let want = (10f64 * 20.0 * 30.0 * 40.0 * 50.0).powf(0.2);
        assert!((g - want).abs() < 1e-9, "{g} vs {want}");
    }
}
