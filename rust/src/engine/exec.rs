//! Plan execution: vectorized operators over rowsets.
//!
//! The heavy operators (aggregate, join, sort) run on the columnar key
//! codec in [`super::hash`]: group/join keys are encoded once per batch
//! into flat fixed-stride byte rows with precomputed hashes, grouping and
//! probing compare `&[u8]` slices, and aggregation runs typed grouped
//! kernels over raw `&[i64]`/`&[f64]` column slices. Output
//! materialization goes through typed gathers (`RowSet::gather`) instead
//! of per-cell `Value` round trips.
//!
//! Expressions (projections, predicates, group/join/sort keys) run on the
//! columnar kernels in `engine::expr`; residual join predicates evaluate
//! over the `l_idx`/`r_idx` gather vectors on only their referenced
//! columns, before the wide output is materialized.
//!
//! ## Morsel-driven parallelism
//!
//! The hot operators split their input into contiguous row-range
//! *morsels* ([`MORSEL_MIN_ROWS`] rows or more each) and evaluate them on
//! scoped worker threads (`std::thread::scope`; the crate deliberately
//! has no rayon dependency). [`ExecContext::parallelism`] caps the worker
//! count — it defaults to [`default_parallelism`] (the
//! `SNOWPARK_PARALLELISM` env var, else the host's available cores) and
//! is derived from the warehouse shape by `Session` (one worker per
//! interpreter process on a node). Every parallel path is constructed to
//! be **byte-identical** to the sequential one: expression morsels
//! concatenate in row order, aggregation merges thread-local key-codec
//! tables into global first-seen group order, joins probe a shared
//! hash-partitioned table whose match order equals a single-table build,
//! and sort merges per-morsel runs under the same index-tiebroken total
//! order. `parallelism = 1` runs fully single-threaded on the
//! sequential code paths (one structural difference: the join probe
//! goes through the same partitioned-table API with one partition).
//!
//! The legacy row-at-a-time paths (including row-wise expression
//! evaluation) are kept behind `ExecContext::vectorized = false` for
//! differential tests and the `groupby_kernels`/`expr_kernels` ablations
//! (`benches/ablations.rs`).

use std::cmp::Ordering;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::sql::ast::{Expr, JoinKind, OrderKey};
use crate::types::{Column, DataType, Field, RowSet, Schema, Value};
use crate::udf::{UdafState, UdfRegistry, UdfStatsStore};

use super::catalog::Catalog;
use super::expr::{
    eval_expr, eval_expr_rowwise, eval_predicate, eval_predicate_rowwise, eval_row,
    resolve_column,
};
use super::hash::{
    assign_group_ids, EncodedKeys, JoinTable, KeyDict, KeyMode, PartitionedJoinTable,
};
use super::key::KeyValue;
use super::plan::{AggCall, AggFunc, Plan};

/// Minimum rows per morsel: below this, thread spawn + merge overhead
/// dominates and operators stay sequential.
pub const MORSEL_MIN_ROWS: usize = 4096;

/// The default intra-query parallelism: the `SNOWPARK_PARALLELISM`
/// environment variable when set to a positive integer, otherwise the
/// host's available cores.
pub fn default_parallelism() -> usize {
    if let Ok(s) = std::env::var("SNOWPARK_PARALLELISM") {
        if let Ok(n) = s.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Everything an operator needs at execution time.
pub struct ExecContext {
    /// Table catalog queries scan from.
    pub catalog: Arc<Catalog>,
    /// Registered user-defined functions (scalar/vectorized/table/agg).
    pub udfs: Arc<UdfRegistry>,
    /// Historical per-UDF cost statistics (feeds the §IV.C decision).
    pub udf_stats: Arc<UdfStatsStore>,
    /// Run expressions on the columnar kernels and aggregate/join/sort on
    /// the columnar key codec (the default). The row-at-a-time paths
    /// remain for differential testing and the `groupby_kernels` /
    /// `expr_kernels` ablations.
    pub vectorized: bool,
    /// Maximum worker threads for morsel-driven operators. `1` (or any
    /// input smaller than two morsels) takes the exact sequential code
    /// path; larger values parallelize scans/filters/projections,
    /// aggregation, join build/probe, and sort. Defaults to
    /// [`default_parallelism`]; `Session` derives it from the warehouse
    /// shape (`procs_per_node`).
    pub parallelism: usize,
}

impl ExecContext {
    /// Context with the default (vectorized) execution paths.
    pub fn new(catalog: Arc<Catalog>, udfs: Arc<UdfRegistry>) -> Self {
        Self {
            catalog,
            udfs,
            udf_stats: Arc::new(UdfStatsStore::new()),
            vectorized: true,
            parallelism: default_parallelism(),
        }
    }

    /// Toggle the vectorized paths (expressions + key codec) on or off.
    pub fn with_vectorized(mut self, on: bool) -> Self {
        self.vectorized = on;
        self
    }

    /// Set the morsel-parallel worker-thread cap (clamped to ≥ 1).
    pub fn with_parallelism(mut self, threads: usize) -> Self {
        self.parallelism = threads.max(1);
        self
    }
}

/// Worker threads a morsel-parallel operator should use over `n` rows:
/// 1 (single-threaded sequential execution) unless the context allows
/// more and every worker gets at least [`MORSEL_MIN_ROWS`] rows.
fn parallel_threads(n: usize, ctx: &ExecContext) -> usize {
    if !ctx.vectorized || ctx.parallelism <= 1 {
        return 1;
    }
    (n / MORSEL_MIN_ROWS).clamp(1, ctx.parallelism)
}

/// Split `n` rows into `threads` contiguous `(offset, len)` morsels of
/// near-equal size (never empty).
fn morsel_ranges(n: usize, threads: usize) -> Vec<(usize, usize)> {
    let t = threads.min(n).max(1);
    let base = n / t;
    let rem = n % t;
    let mut ranges = Vec::with_capacity(t);
    let mut off = 0;
    for i in 0..t {
        let len = base + usize::from(i < rem);
        ranges.push((off, len));
        off += len;
    }
    ranges
}

/// Run `f(morsel_index, offset, len)` for every morsel on scoped worker
/// threads, collecting results in morsel order. The first error in
/// morsel (row-range) order wins, matching the sequential path, and
/// worker panics propagate to the caller.
fn par_morsels<T, F>(ranges: &[(usize, usize)], f: F) -> Result<Vec<T>>
where
    T: Send,
    F: Fn(usize, usize, usize) -> Result<T> + Sync,
{
    std::thread::scope(|s| {
        let f = &f;
        let handles: Vec<_> = ranges
            .iter()
            .enumerate()
            .map(|(i, &(off, len))| s.spawn(move || f(i, off, len)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|e| std::panic::resume_unwind(e)))
            .collect()
    })
}

/// Does the expression call a registered *vectorized* UDF anywhere?
/// Vectorized UDFs run batch-at-a-time and may be batch-dependent (the
/// XLA min-max scaler computes statistics over the batch it is handed),
/// so expressions containing one keep whole-input evaluation instead of
/// morsel-splitting — splitting would move the batch boundary and change
/// their results.
fn has_vectorized_udf(e: &Expr, udfs: &UdfRegistry) -> bool {
    match e {
        Expr::Func { name, args } => {
            udfs.has_vectorized(name) || args.iter().any(|a| has_vectorized_udf(a, udfs))
        }
        Expr::Unary { expr, .. } | Expr::IsNull { expr, .. } => has_vectorized_udf(expr, udfs),
        Expr::Binary { left, right, .. } => {
            has_vectorized_udf(left, udfs) || has_vectorized_udf(right, udfs)
        }
        Expr::InList { expr, list, .. } => {
            has_vectorized_udf(expr, udfs) || list.iter().any(|a| has_vectorized_udf(a, udfs))
        }
        Expr::Between { expr, low, high, .. } => {
            has_vectorized_udf(expr, udfs)
                || has_vectorized_udf(low, udfs)
                || has_vectorized_udf(high, udfs)
        }
        Expr::Case { branches, else_value } => {
            branches
                .iter()
                .any(|(c, v)| has_vectorized_udf(c, udfs) || has_vectorized_udf(v, udfs))
                || else_value
                    .as_ref()
                    .map_or(false, |e| has_vectorized_udf(e, udfs))
        }
        Expr::Literal(_) | Expr::Column(_) | Expr::Star => false,
    }
}

/// The morsel plan for evaluating `e` over `rows`: the morsel ranges
/// plus the narrow projection (schema + column indices) each morsel
/// slices — only referenced columns are copied, so wide tables don't get
/// duplicated for a predicate touching one column. `None` means evaluate
/// whole-input: sequential context, too few rows, a batch-dependent
/// vectorized UDF, or a column-free (constant-foldable) expression.
/// Single source of truth for [`eval`], [`eval_pred`], and the
/// `QueryStats` morsel counters. Names resolve against the *full*
/// schema, so resolution (and its errors) match whole-input evaluation.
#[allow(clippy::type_complexity)]
fn morsel_plan(
    e: &Expr,
    rows: &RowSet,
    ctx: &ExecContext,
) -> Result<Option<(Vec<(usize, usize)>, Schema, Vec<usize>)>> {
    if !ctx.vectorized {
        return Ok(None);
    }
    let threads = parallel_threads(rows.num_rows(), ctx);
    if threads <= 1 || has_vectorized_udf(e, &ctx.udfs) {
        return Ok(None);
    }
    let mut names = Vec::new();
    e.referenced_columns(&mut names);
    if names.is_empty() {
        return Ok(None);
    }
    let mut needed: Vec<usize> = names
        .iter()
        .map(|n| resolve_column(&rows.schema, n))
        .collect::<Result<_>>()?;
    needed.sort_unstable();
    needed.dedup();
    let fields = needed.iter().map(|&i| rows.schema.field(i).clone()).collect();
    Ok(Some((morsel_ranges(rows.num_rows(), threads), Schema::new(fields), needed)))
}

/// One morsel's input: the needed columns sliced to `[off, off + len)`.
fn narrow_morsel(
    rows: &RowSet,
    schema: &Schema,
    needed: &[usize],
    off: usize,
    len: usize,
) -> Result<RowSet> {
    let cols: Vec<Column> = needed.iter().map(|&ci| rows.column(ci).slice(off, len)).collect();
    RowSet::new(schema.clone(), cols)
}

/// Evaluate an expression through the path selected by `ctx.vectorized`,
/// splitting large inputs into morsels evaluated on worker threads. The
/// per-morsel columns concatenate in row order, so the result (values
/// and validity representation) is identical to whole-input evaluation.
fn eval(e: &Expr, rows: &RowSet, ctx: &ExecContext) -> Result<Column> {
    if !ctx.vectorized {
        return eval_expr_rowwise(e, rows, &ctx.udfs);
    }
    let (ranges, schema, needed) = match morsel_plan(e, rows, ctx)? {
        Some(plan) => plan,
        None => return eval_expr(e, rows, &ctx.udfs),
    };
    let parts = par_morsels(&ranges, |_, off, len| {
        let morsel = narrow_morsel(rows, &schema, &needed, off, len)?;
        eval_expr(e, &morsel, &ctx.udfs)
    })?;
    let mut iter = parts.into_iter();
    let mut out = iter.next().expect("at least one morsel");
    for part in iter {
        out.append(&part)?;
    }
    Ok(out)
}

/// Evaluate a predicate mask through the path selected by
/// `ctx.vectorized`, morsel-parallel like [`eval`].
fn eval_pred(e: &Expr, rows: &RowSet, ctx: &ExecContext) -> Result<Vec<bool>> {
    if !ctx.vectorized {
        return eval_predicate_rowwise(e, rows, &ctx.udfs);
    }
    let (ranges, schema, needed) = match morsel_plan(e, rows, ctx)? {
        Some(plan) => plan,
        None => return eval_predicate(e, rows, &ctx.udfs),
    };
    let parts = par_morsels(&ranges, |_, off, len| {
        let morsel = narrow_morsel(rows, &schema, &needed, off, len)?;
        eval_predicate(e, &morsel, &ctx.udfs)
    })?;
    let mut mask = Vec::with_capacity(rows.num_rows());
    for part in parts {
        mask.extend_from_slice(&part);
    }
    Ok(mask)
}

/// Morsel count [`eval`]/[`eval_pred`] will actually use for `e` over
/// `rows` — 1 whenever [`morsel_plan`] forces whole-input evaluation.
/// Keeps the `QueryStats` morsel columns honest.
fn eval_threads(e: &Expr, rows: &RowSet, ctx: &ExecContext) -> u64 {
    match morsel_plan(e, rows, ctx) {
        Ok(Some((ranges, _, _))) => ranges.len() as u64,
        _ => 1,
    }
}

/// Worst-case (max) morsel count across a projection's expressions; the
/// pass-through markers (`*`, `__drop_hidden`) copy columns without
/// evaluation and count as 1.
fn project_threads(exprs: &[(Expr, String)], rows: &RowSet, ctx: &ExecContext) -> u64 {
    exprs
        .iter()
        .map(|(e, _)| match e {
            Expr::Star => 1,
            Expr::Func { name, .. } if name == "__drop_hidden" => 1,
            _ => eval_threads(e, rows, ctx),
        })
        .max()
        .unwrap_or(1)
}

/// Rows processed and wall time spent in one operator class.
#[derive(Debug, Default, Clone, Copy)]
pub struct OpStats {
    /// How many times this operator class ran in the query.
    pub invocations: u64,
    /// Total input rows across invocations.
    pub rows_in: u64,
    /// Total output rows across invocations.
    pub rows_out: u64,
    /// Morsels across invocations — the worker-thread count of each
    /// invocation's widest parallel stage (for a projection: the max
    /// across its expressions). The static scheduler hands each worker
    /// one contiguous morsel; a sequential invocation contributes 1.
    pub morsels: u64,
    /// Largest worker-thread count any single invocation used.
    pub max_threads: u64,
    /// Total wall time in nanoseconds.
    pub nanos: u64,
}

impl OpStats {
    fn record(&mut self, rows_in: u64, rows_out: u64, morsels: u64, started: Instant) {
        self.invocations += 1;
        self.rows_in += rows_in;
        self.rows_out += rows_out;
        self.morsels += morsels;
        self.max_threads = self.max_threads.max(morsels);
        self.nanos += started.elapsed().as_nanos() as u64;
    }
}

/// Per-query execution statistics: per-operator row counts and timings.
#[derive(Debug, Default, Clone)]
pub struct QueryStats {
    /// Rows read by all table scans.
    pub rows_scanned: u64,
    /// Rows in the query's final result.
    pub rows_output: u64,
    /// Scan / table-function operator stats.
    pub scan: OpStats,
    /// Filter (WHERE / HAVING) operator stats.
    pub filter: OpStats,
    /// Projection operator stats.
    pub project: OpStats,
    /// Hash-aggregate operator stats.
    pub aggregate: OpStats,
    /// Join operator stats.
    pub join: OpStats,
    /// Sort / top-k operator stats.
    pub sort: OpStats,
    /// Limit operator stats.
    pub limit: OpStats,
}

impl QueryStats {
    fn operators(&self) -> [(&'static str, &OpStats); 7] {
        [
            ("scan", &self.scan),
            ("filter", &self.filter),
            ("project", &self.project),
            ("aggregate", &self.aggregate),
            ("join", &self.join),
            ("sort", &self.sort),
            ("limit", &self.limit),
        ]
    }

    /// Aligned per-operator report (`snowparkd run-sql --stats` prints it).
    pub fn report(&self) -> String {
        let mut out = format!(
            "{:<10} {:>6} {:>12} {:>12} {:>8} {:>8} {:>12}\n",
            "operator", "calls", "rows_in", "rows_out", "morsels", "threads", "time"
        );
        for (name, op) in self.operators() {
            if op.invocations == 0 {
                continue;
            }
            out.push_str(&format!(
                "{:<10} {:>6} {:>12} {:>12} {:>8} {:>8} {:>9.3}ms\n",
                name,
                op.invocations,
                op.rows_in,
                op.rows_out,
                op.morsels,
                op.max_threads,
                op.nanos as f64 / 1e6
            ));
        }
        out
    }
}

/// Execute a plan to completion.
pub fn execute_plan(plan: &Plan, ctx: &ExecContext) -> Result<RowSet> {
    Ok(execute_plan_with_stats(plan, ctx)?.0)
}

/// Execute a plan, returning per-operator row counts and timings.
pub fn execute_plan_with_stats(plan: &Plan, ctx: &ExecContext) -> Result<(RowSet, QueryStats)> {
    let mut stats = QueryStats::default();
    let out = exec(plan, ctx, &mut stats)?;
    stats.rows_output = out.num_rows() as u64;
    Ok((out, stats))
}

fn exec(plan: &Plan, ctx: &ExecContext, stats: &mut QueryStats) -> Result<RowSet> {
    match plan {
        Plan::Scan { table, alias: _ } => {
            let t0 = Instant::now();
            let rs = ctx.catalog.get(table)?;
            let n = rs.num_rows() as u64;
            stats.rows_scanned += n;
            stats.scan.record(n, n, 1, t0);
            Ok(rs)
        }
        Plan::TableFunc { name, args, alias: _ } => {
            let t0 = Instant::now();
            let rs = if name == "__dual" {
                // SELECT without FROM: one row, zero columns.
                RowSet::new(
                    Schema::new(vec![Field::new("__dummy", DataType::Int64)]),
                    vec![Column::from_i64(vec![0])],
                )
                .unwrap()
            } else {
                // Evaluate constant args against a dual row.
                let dual = RowSet::new(
                    Schema::new(vec![Field::new("__dummy", DataType::Int64)]),
                    vec![Column::from_i64(vec![0])],
                )
                .unwrap();
                let arg_vals: Vec<Value> = args
                    .iter()
                    .map(|a| eval_row(a, &dual, 0, &ctx.udfs))
                    .collect::<Result<_>>()?;
                ctx.catalog
                    .get(name)
                    .or_else(|_| ctx.udfs.call_udtf(name, &arg_vals))?
            };
            let n = rs.num_rows() as u64;
            stats.scan.record(n, n, 1, t0);
            Ok(rs)
        }
        Plan::Filter { input, predicate } => {
            let rows = exec(input, ctx, stats)?;
            let t0 = Instant::now();
            let morsels = eval_threads(predicate, &rows, ctx);
            let mask = eval_pred(predicate, &rows, ctx)?;
            let out = rows.filter(&mask);
            stats
                .filter
                .record(rows.num_rows() as u64, out.num_rows() as u64, morsels, t0);
            Ok(out)
        }
        Plan::Project { input, exprs } => {
            let rows = exec(input, ctx, stats)?;
            let t0 = Instant::now();
            let morsels = project_threads(exprs, &rows, ctx);
            let out = project(&rows, exprs, ctx)?;
            stats
                .project
                .record(rows.num_rows() as u64, out.num_rows() as u64, morsels, t0);
            Ok(out)
        }
        Plan::Aggregate { input, group, aggs } => {
            let rows = exec(input, ctx, stats)?;
            let t0 = Instant::now();
            let morsels = parallel_threads(rows.num_rows(), ctx) as u64;
            let out = aggregate(&rows, group, aggs, ctx)?;
            stats
                .aggregate
                .record(rows.num_rows() as u64, out.num_rows() as u64, morsels, t0);
            Ok(out)
        }
        Plan::Join { left, right, kind, equi, residual } => {
            let l = exec(left, ctx, stats)?;
            let r = exec(right, ctx, stats)?;
            let t0 = Instant::now();
            // Probe-side morsels; the build side partitions separately.
            // A cross join (no equi keys) runs its nested loop
            // sequentially, so it reports 1.
            let morsels = if equi.is_empty() {
                1
            } else {
                parallel_threads(l.num_rows(), ctx) as u64
            };
            let out = join(&l, &r, *kind, equi, residual.as_ref(), ctx, plan)?;
            stats.join.record(
                (l.num_rows() + r.num_rows()) as u64,
                out.num_rows() as u64,
                morsels,
                t0,
            );
            Ok(out)
        }
        Plan::Sort { input, keys } => {
            let rows = exec(input, ctx, stats)?;
            let t0 = Instant::now();
            let morsels = parallel_threads(rows.num_rows(), ctx) as u64;
            let out = sort(&rows, keys, ctx, None)?;
            stats
                .sort
                .record(rows.num_rows() as u64, out.num_rows() as u64, morsels, t0);
            Ok(out)
        }
        Plan::Limit { input, n } => {
            // `ORDER BY ... LIMIT k` short-circuits into a top-k partial
            // sort instead of sorting the full input. The sort may sit
            // directly below, or below the hidden-column-dropping
            // projection the planner inserts.
            match input.as_ref() {
                Plan::Sort { input: sort_input, keys } => {
                    let rows = exec(sort_input, ctx, stats)?;
                    let t0 = Instant::now();
                    // LIMIT 0 short-circuits to an empty result without
                    // sorting runs.
                    let morsels =
                        if *n == 0 { 1 } else { parallel_threads(rows.num_rows(), ctx) as u64 };
                    let out = sort(&rows, keys, ctx, Some(*n))?;
                    stats
                        .sort
                        .record(rows.num_rows() as u64, out.num_rows() as u64, morsels, t0);
                    Ok(out)
                }
                Plan::Project { input: proj_input, exprs }
                    if matches!(proj_input.as_ref(), Plan::Sort { .. }) =>
                {
                    if let Plan::Sort { input: sort_input, keys } = proj_input.as_ref() {
                        let rows = exec(sort_input, ctx, stats)?;
                        let t0 = Instant::now();
                        let morsels =
                            if *n == 0 { 1 } else { parallel_threads(rows.num_rows(), ctx) as u64 };
                        let sorted = sort(&rows, keys, ctx, Some(*n))?;
                        stats
                            .sort
                            .record(rows.num_rows() as u64, sorted.num_rows() as u64, morsels, t0);
                        let t0 = Instant::now();
                        let morsels = project_threads(exprs, &sorted, ctx);
                        let out = project(&sorted, exprs, ctx)?;
                        stats
                            .project
                            .record(sorted.num_rows() as u64, out.num_rows() as u64, morsels, t0);
                        Ok(out)
                    } else {
                        unreachable!("guarded by matches! above")
                    }
                }
                _ => {
                    let rows = exec(input, ctx, stats)?;
                    let t0 = Instant::now();
                    let out = rows.slice(0, (*n).min(rows.num_rows()));
                    stats
                        .limit
                        .record(rows.num_rows() as u64, out.num_rows() as u64, 1, t0);
                    Ok(out)
                }
            }
        }
    }
}

fn project(rows: &RowSet, exprs: &[(Expr, String)], ctx: &ExecContext) -> Result<RowSet> {
    let mut fields = Vec::new();
    let mut columns = Vec::new();
    for (e, name) in exprs {
        // Marker from the planner: keep everything except hidden sort keys.
        if matches!(e, Expr::Func { name, .. } if name == "__drop_hidden") {
            for (f, c) in rows.schema.fields.iter().zip(&rows.columns) {
                if !f.name.starts_with("__sort_") {
                    fields.push(f.clone());
                    columns.push(c.clone());
                }
            }
            continue;
        }
        if matches!(e, Expr::Star) {
            // Wildcard expansion mixed with other expressions.
            for (f, c) in rows.schema.fields.iter().zip(&rows.columns) {
                fields.push(f.clone());
                columns.push(c.clone());
            }
            continue;
        }
        let col = eval(e, rows, ctx)?;
        fields.push(Field::new(name.clone(), col.data_type()));
        columns.push(col);
    }
    RowSet::new(Schema::new(fields), columns)
}

// ---------------------------------------------------------------- aggregate

struct GroupState {
    key_row: Vec<Value>,
    accs: Vec<AggAcc>,
}

enum AggAcc {
    CountStar(i64),
    Count(i64),
    /// SUM accumulates exactly in `i64` while every input is an integer,
    /// switching to `f64` on the first float input or on `i64` overflow
    /// (fixes silent precision loss past 2^53).
    Sum { isum: i64, fsum: f64, float_mode: bool, any: bool },
    Avg { sum: f64, n: i64 },
    Min(Option<Value>),
    Max(Option<Value>),
    Udaf(Box<dyn crate::udf::UdafState>),
}

impl AggAcc {
    fn new(call: &AggCall, udfs: &UdfRegistry) -> Result<AggAcc> {
        Ok(match call.func {
            AggFunc::CountStar => AggAcc::CountStar(0),
            AggFunc::Count => AggAcc::Count(0),
            AggFunc::Sum => AggAcc::Sum { isum: 0, fsum: 0.0, float_mode: false, any: false },
            AggFunc::Avg => AggAcc::Avg { sum: 0.0, n: 0 },
            AggFunc::Min => AggAcc::Min(None),
            AggFunc::Max => AggAcc::Max(None),
            AggFunc::Udaf => {
                let udaf = udfs
                    .udaf(&call.name)
                    .ok_or_else(|| anyhow!("no UDAF {:?}", call.name))?;
                AggAcc::Udaf((udaf.factory)())
            }
        })
    }

    fn update(&mut self, args: &[Value]) -> Result<()> {
        match self {
            AggAcc::CountStar(n) => *n += 1,
            AggAcc::Count(n) => {
                if !args[0].is_null() {
                    *n += 1;
                }
            }
            AggAcc::Sum { isum, fsum, float_mode, any } => match &args[0] {
                Value::Null => {}
                Value::Int(i) => {
                    *any = true;
                    if *float_mode {
                        *fsum += *i as f64;
                    } else {
                        match isum.checked_add(*i) {
                            Some(s) => *isum = s,
                            None => {
                                *float_mode = true;
                                *fsum = *isum as f64 + *i as f64;
                            }
                        }
                    }
                }
                v => {
                    let x = v
                        .as_f64()
                        .ok_or_else(|| anyhow!("SUM over non-numeric {v}"))?;
                    *any = true;
                    if !*float_mode {
                        *float_mode = true;
                        *fsum = *isum as f64;
                    }
                    *fsum += x;
                }
            },
            AggAcc::Avg { sum, n } => {
                if !args[0].is_null() {
                    *sum += args[0]
                        .as_f64()
                        .ok_or_else(|| anyhow!("AVG over non-numeric {}", args[0]))?;
                    *n += 1;
                }
            }
            AggAcc::Min(cur) => {
                if !args[0].is_null() {
                    let replace = match cur {
                        None => true,
                        Some(c) => {
                            args[0].sql_cmp(c) == Some(std::cmp::Ordering::Less)
                        }
                    };
                    if replace {
                        *cur = Some(args[0].clone());
                    }
                }
            }
            AggAcc::Max(cur) => {
                if !args[0].is_null() {
                    let replace = match cur {
                        None => true,
                        Some(c) => {
                            args[0].sql_cmp(c) == Some(std::cmp::Ordering::Greater)
                        }
                    };
                    if replace {
                        *cur = Some(args[0].clone());
                    }
                }
            }
            AggAcc::Udaf(state) => state.update(args)?,
        }
        Ok(())
    }

    fn finish(&self) -> Result<Value> {
        Ok(match self {
            AggAcc::CountStar(n) | AggAcc::Count(n) => Value::Int(*n),
            AggAcc::Sum { isum, fsum, float_mode, any } => {
                if !any {
                    Value::Null
                } else if *float_mode {
                    Value::Float(*fsum)
                } else {
                    Value::Int(*isum)
                }
            }
            AggAcc::Avg { sum, n } => {
                if *n == 0 {
                    Value::Null
                } else {
                    Value::Float(sum / *n as f64)
                }
            }
            AggAcc::Min(v) | AggAcc::Max(v) => v.clone().unwrap_or(Value::Null),
            AggAcc::Udaf(state) => state.finish()?,
        })
    }
}

fn aggregate(
    rows: &RowSet,
    group: &[(Expr, String)],
    aggs: &[AggCall],
    ctx: &ExecContext,
) -> Result<RowSet> {
    // Evaluate group keys and aggregate arguments as columns first
    // (vectorized), then group.
    let key_cols: Vec<Column> = group
        .iter()
        .map(|(e, _)| eval(e, rows, ctx))
        .collect::<Result<_>>()?;
    let arg_cols: Vec<Vec<Column>> = aggs
        .iter()
        .map(|a| {
            a.args
                .iter()
                .map(|e| eval(e, rows, ctx))
                .collect::<Result<Vec<_>>>()
        })
        .collect::<Result<_>>()?;
    if !ctx.vectorized {
        return aggregate_rowwise(rows, group, aggs, &key_cols, &arg_cols, ctx);
    }
    let threads = parallel_threads(rows.num_rows(), ctx);
    if threads <= 1 {
        aggregate_vectorized(rows, group, aggs, &key_cols, &arg_cols, ctx)
    } else {
        aggregate_parallel(rows, group, aggs, &key_cols, &arg_cols, ctx, threads)
    }
}

/// Two-pass vectorized aggregation: (1) assign each row a dense group id
/// via the key codec, (2) run typed grouped kernels over raw column
/// slices. Group output order is first-seen order, like the legacy path.
fn aggregate_vectorized(
    rows: &RowSet,
    group: &[(Expr, String)],
    aggs: &[AggCall],
    key_cols: &[Column],
    arg_cols: &[Vec<Column>],
    ctx: &ExecContext,
) -> Result<RowSet> {
    let n = rows.num_rows();
    // Pass 1: dense group ids.
    let (group_of, rep_rows, n_groups) = if group.is_empty() {
        // Global aggregation: one group, even over empty input.
        (vec![0u32; n], Vec::new(), 1)
    } else {
        let mut dict = KeyDict::new();
        let keys = EncodedKeys::encode(key_cols, KeyMode::Group, &mut dict);
        let g = assign_group_ids(&keys);
        let n_groups = g.n_groups();
        (g.ids, g.rep_rows, n_groups)
    };

    // Pass 2: key columns gather from the representative rows; aggregates
    // run typed kernels.
    let mut fields = Vec::with_capacity(group.len() + aggs.len());
    let mut columns = Vec::with_capacity(group.len() + aggs.len());
    for ((_, name), col) in group.iter().zip(key_cols) {
        let out = col.take(&rep_rows);
        fields.push(Field::new(name.clone(), out.data_type()));
        columns.push(out);
    }
    for (call, cols) in aggs.iter().zip(arg_cols) {
        let out = agg_kernel(call, cols, &group_of, n_groups, ctx)?;
        fields.push(Field::new(call.out_name.clone(), out.data_type()));
        columns.push(out);
    }
    RowSet::new(Schema::new(fields), columns)
}

/// Dispatch one aggregate call to its typed grouped kernel; UDAFs fall
/// back to the accumulator path (per group, not per row-key).
fn agg_kernel(
    call: &AggCall,
    args: &[Column],
    gids: &[u32],
    n_groups: usize,
    ctx: &ExecContext,
) -> Result<Column> {
    match call.func {
        AggFunc::CountStar => {
            let mut counts = vec![0i64; n_groups];
            for &g in gids {
                counts[g as usize] += 1;
            }
            Ok(Column::from_i64(counts))
        }
        AggFunc::Count => Ok(count_by_group(&args[0], gids, n_groups)),
        AggFunc::Sum => sum_by_group(&args[0], gids, n_groups),
        AggFunc::Avg => avg_by_group(&args[0], gids, n_groups),
        AggFunc::Min => Ok(min_max_by_group(&args[0], gids, n_groups, true)),
        AggFunc::Max => Ok(min_max_by_group(&args[0], gids, n_groups, false)),
        AggFunc::Udaf => udaf_by_group(call, args, gids, n_groups, ctx),
    }
}

/// All-NULL Float64 column — the type the legacy value-derived schema
/// assigned when an aggregate produced no non-NULL value at all.
fn null_f64_column(n: usize) -> Column {
    Column::Float64 {
        data: vec![0.0; n],
        valid: if n > 0 { Some(vec![false; n]) } else { None },
    }
}

/// `None` when every group has a value (no validity mask needed).
fn mask_from_any(any: &[bool]) -> Option<Vec<bool>> {
    if any.iter().all(|&a| a) {
        None
    } else {
        Some(any.to_vec())
    }
}

/// SUM/AVG over a non-numeric column: error on the first non-NULL value
/// (matching the legacy row path); all-NULL input yields NULL sums.
fn non_numeric_agg(what: &str, col: &Column, n_groups: usize) -> Result<Column> {
    for r in 0..col.len() {
        if col.is_valid(r) {
            bail!("{what} over non-numeric {}", col.value(r));
        }
    }
    Ok(null_f64_column(n_groups))
}

fn count_by_group(col: &Column, gids: &[u32], n_groups: usize) -> Column {
    let mut counts = vec![0i64; n_groups];
    match col.validity() {
        None => {
            for &g in gids {
                counts[g as usize] += 1;
            }
        }
        Some(valid) => {
            for (r, &g) in gids.iter().enumerate() {
                if valid[r] {
                    counts[g as usize] += 1;
                }
            }
        }
    }
    Column::from_i64(counts)
}

/// Grouped SUM. Int64 inputs accumulate in `i64` with overflow-checked
/// widening to `f64` (per group; any overflow widens the output column).
fn sum_by_group(col: &Column, gids: &[u32], n_groups: usize) -> Result<Column> {
    match col {
        Column::Int64 { data, valid } => {
            let mut isums = vec![0i64; n_groups];
            // Allocated lazily on the first overflow.
            let mut fsums: Vec<f64> = Vec::new();
            let mut overflowed: Vec<bool> = Vec::new();
            let mut any = vec![false; n_groups];
            for (r, &g) in gids.iter().enumerate() {
                if valid.as_ref().map_or(true, |v| v[r]) {
                    let g = g as usize;
                    any[g] = true;
                    if !overflowed.is_empty() && overflowed[g] {
                        fsums[g] += data[r] as f64;
                    } else {
                        match isums[g].checked_add(data[r]) {
                            Some(s) => isums[g] = s,
                            None => {
                                if overflowed.is_empty() {
                                    overflowed = vec![false; n_groups];
                                    fsums = vec![0.0; n_groups];
                                }
                                overflowed[g] = true;
                                fsums[g] = isums[g] as f64 + data[r] as f64;
                            }
                        }
                    }
                }
            }
            if !any.iter().any(|&a| a) {
                return Ok(null_f64_column(n_groups));
            }
            if overflowed.is_empty() {
                Ok(Column::Int64 { data: isums, valid: mask_from_any(&any) })
            } else {
                // At least one group overflowed i64: widen the column.
                let data: Vec<f64> = (0..n_groups)
                    .map(|g| if overflowed[g] { fsums[g] } else { isums[g] as f64 })
                    .collect();
                Ok(Column::Float64 { data, valid: mask_from_any(&any) })
            }
        }
        Column::Float64 { data, valid } => {
            let mut sums = vec![0.0f64; n_groups];
            let mut any = vec![false; n_groups];
            for (r, &g) in gids.iter().enumerate() {
                if valid.as_ref().map_or(true, |v| v[r]) {
                    sums[g as usize] += data[r];
                    any[g as usize] = true;
                }
            }
            if !any.iter().any(|&a| a) {
                return Ok(null_f64_column(n_groups));
            }
            Ok(Column::Float64 { data: sums, valid: mask_from_any(&any) })
        }
        other => non_numeric_agg("SUM", other, n_groups),
    }
}

fn avg_by_group(col: &Column, gids: &[u32], n_groups: usize) -> Result<Column> {
    let mut sums = vec![0.0f64; n_groups];
    let mut counts = vec![0i64; n_groups];
    match col {
        Column::Int64 { data, valid } => {
            for (r, &g) in gids.iter().enumerate() {
                if valid.as_ref().map_or(true, |v| v[r]) {
                    sums[g as usize] += data[r] as f64;
                    counts[g as usize] += 1;
                }
            }
        }
        Column::Float64 { data, valid } => {
            for (r, &g) in gids.iter().enumerate() {
                if valid.as_ref().map_or(true, |v| v[r]) {
                    sums[g as usize] += data[r];
                    counts[g as usize] += 1;
                }
            }
        }
        other => return non_numeric_agg("AVG", other, n_groups),
    }
    let data: Vec<f64> = sums
        .iter()
        .zip(&counts)
        .map(|(s, &c)| if c > 0 { s / c as f64 } else { 0.0 })
        .collect();
    let any: Vec<bool> = counts.iter().map(|&c| c > 0).collect();
    Ok(Column::Float64 { data, valid: mask_from_any(&any) })
}

/// Grouped MIN/MAX via best-row indices: one typed compare per row, then a
/// single typed gather — no `Value` comparisons, no string clones.
fn min_max_by_group(col: &Column, gids: &[u32], n_groups: usize, is_min: bool) -> Column {
    fn scan_best<F: Fn(usize, usize) -> bool>(
        gids: &[u32],
        valid: Option<&[bool]>,
        best: &mut [i64],
        better: F,
    ) {
        for (r, &g) in gids.iter().enumerate() {
            if valid.map_or(true, |v| v[r]) {
                let b = &mut best[g as usize];
                if *b < 0 || better(r, *b as usize) {
                    *b = r as i64;
                }
            }
        }
    }

    let mut best: Vec<i64> = vec![-1; n_groups];
    let valid = col.validity();
    match col {
        Column::Int64 { data, .. } => scan_best(gids, valid, &mut best, |r, b| {
            if is_min {
                data[r] < data[b]
            } else {
                data[r] > data[b]
            }
        }),
        Column::Float64 { data, .. } => scan_best(gids, valid, &mut best, |r, b| {
            // Mirrors `Value::sql_cmp`: NaN compares as unknown, so it
            // never replaces the current best.
            let ord = data[r].partial_cmp(&data[b]);
            if is_min {
                ord == Some(Ordering::Less)
            } else {
                ord == Some(Ordering::Greater)
            }
        }),
        Column::Utf8 { data, .. } => scan_best(gids, valid, &mut best, |r, b| {
            if is_min {
                data[r] < data[b]
            } else {
                data[r] > data[b]
            }
        }),
        Column::Bool { data, .. } => scan_best(gids, valid, &mut best, |r, b| {
            if is_min {
                !data[r] & data[b]
            } else {
                data[r] & !data[b]
            }
        }),
    }
    if best.iter().all(|&b| b < 0) {
        // No non-NULL input anywhere: legacy schema derivation fell back
        // to Float64.
        return null_f64_column(n_groups);
    }
    col.gather_opt(&best)
}

/// UDAF fallback: accumulator states per dense group id (still avoids the
/// per-row key materialization of the legacy path).
fn udaf_by_group(
    call: &AggCall,
    args: &[Column],
    gids: &[u32],
    n_groups: usize,
    ctx: &ExecContext,
) -> Result<Column> {
    let udaf = ctx
        .udfs
        .udaf(&call.name)
        .ok_or_else(|| anyhow!("no UDAF {:?}", call.name))?;
    let mut states: Vec<Box<dyn crate::udf::UdafState>> =
        (0..n_groups).map(|_| (udaf.factory)()).collect();
    let mut argv: Vec<Value> = Vec::with_capacity(args.len());
    for (r, &g) in gids.iter().enumerate() {
        argv.clear();
        for c in args {
            argv.push(c.value(r));
        }
        states[g as usize].update(&argv)?;
    }
    let mut vals = Vec::with_capacity(n_groups);
    for s in &states {
        vals.push(s.finish()?);
    }
    let mut dt = udaf.return_type;
    if dt == DataType::Int64 && vals.iter().any(|v| matches!(v, Value::Float(_))) {
        dt = DataType::Float64;
    }
    Column::from_values(dt, &vals)
}

// ---------------------------------------------------- parallel aggregation

/// Is row `r` strictly better than the current best row `b` for MIN (or
/// MAX) on `col`? Mirrors the typed comparators in `min_max_by_group` —
/// including NaN comparing as unknown — and is strict, so earlier rows
/// win ties exactly like the sequential scan.
fn min_max_better(col: &Column, r: usize, b: usize, is_min: bool) -> bool {
    match col {
        Column::Int64 { data, .. } => {
            if is_min {
                data[r] < data[b]
            } else {
                data[r] > data[b]
            }
        }
        Column::Float64 { data, .. } => {
            let ord = data[r].partial_cmp(&data[b]);
            if is_min {
                ord == Some(Ordering::Less)
            } else {
                ord == Some(Ordering::Greater)
            }
        }
        Column::Utf8 { data, .. } => {
            if is_min {
                data[r] < data[b]
            } else {
                data[r] > data[b]
            }
        }
        Column::Bool { data, .. } => {
            if is_min {
                !data[r] & data[b]
            } else {
                data[r] & !data[b]
            }
        }
    }
}

/// A mergeable per-group partial state for one aggregate call, built by
/// one morsel worker and folded into the global state by the merge pass.
/// The variant is chosen from the aggregate function and its argument
/// column type, so every morsel of one call produces the same variant.
enum PartialAgg {
    /// COUNT(*) per group.
    CountStar(Vec<i64>),
    /// COUNT(expr) per group (non-NULL cells).
    Count(Vec<i64>),
    /// SUM over Int64: exact i64 accumulation with per-group
    /// overflow-checked widening (mirrors `sum_by_group`). Known caveat:
    /// the sequential scan's widening is sticky on its running prefix, so
    /// a sum that *transiently* overflows i64 mid-scan but lands back in
    /// range comes out Float64 sequentially while exact per-morsel
    /// partials may merge without ever overflowing and stay Int64 (a
    /// more precise answer, but a dtype divergence at the i64 boundary).
    IntSum { isums: Vec<i64>, fsums: Vec<f64>, overflowed: Vec<bool>, any: Vec<bool> },
    /// SUM over Float64.
    FloatSum { sums: Vec<f64>, any: Vec<bool> },
    /// SUM/AVG over a non-numeric column: any non-NULL cell errors at
    /// build time (mirroring `non_numeric_agg`); all-NULL input finishes
    /// as an all-NULL Float64 column.
    NullAgg,
    /// AVG over a numeric column.
    Avg { sums: Vec<f64>, counts: Vec<i64> },
    /// MIN/MAX: best *global* row index per group (`-1` = none yet).
    MinMax { best: Vec<i64>, is_min: bool },
    /// UDAF accumulator states per group, folded via [`UdafState::merge`].
    Udaf(Vec<Box<dyn UdafState>>),
}

impl PartialAgg {
    /// Zeroed partial state for `call` over `n_groups` groups.
    fn empty(
        call: &AggCall,
        args: &[Column],
        n_groups: usize,
        ctx: &ExecContext,
    ) -> Result<PartialAgg> {
        Ok(match call.func {
            AggFunc::CountStar => PartialAgg::CountStar(vec![0; n_groups]),
            AggFunc::Count => PartialAgg::Count(vec![0; n_groups]),
            AggFunc::Sum => match &args[0] {
                Column::Int64 { .. } => PartialAgg::IntSum {
                    isums: vec![0; n_groups],
                    fsums: vec![0.0; n_groups],
                    overflowed: vec![false; n_groups],
                    any: vec![false; n_groups],
                },
                Column::Float64 { .. } => {
                    PartialAgg::FloatSum { sums: vec![0.0; n_groups], any: vec![false; n_groups] }
                }
                _ => PartialAgg::NullAgg,
            },
            AggFunc::Avg => match &args[0] {
                Column::Int64 { .. } | Column::Float64 { .. } => {
                    PartialAgg::Avg { sums: vec![0.0; n_groups], counts: vec![0; n_groups] }
                }
                _ => PartialAgg::NullAgg,
            },
            AggFunc::Min => PartialAgg::MinMax { best: vec![-1; n_groups], is_min: true },
            AggFunc::Max => PartialAgg::MinMax { best: vec![-1; n_groups], is_min: false },
            AggFunc::Udaf => {
                let udaf = ctx
                    .udfs
                    .udaf(&call.name)
                    .ok_or_else(|| anyhow!("no UDAF {:?}", call.name))?;
                PartialAgg::Udaf((0..n_groups).map(|_| (udaf.factory)()).collect())
            }
        })
    }

    /// Accumulate rows `offset..offset + gids.len()` (whose per-row local
    /// group ids are `gids`) into this partial state, in row order.
    fn update(
        &mut self,
        call: &AggCall,
        args: &[Column],
        offset: usize,
        gids: &[u32],
    ) -> Result<()> {
        match self {
            PartialAgg::CountStar(counts) => {
                for &g in gids {
                    counts[g as usize] += 1;
                }
            }
            PartialAgg::Count(counts) => match args[0].validity() {
                None => {
                    for &g in gids {
                        counts[g as usize] += 1;
                    }
                }
                Some(valid) => {
                    for (k, &g) in gids.iter().enumerate() {
                        if valid[offset + k] {
                            counts[g as usize] += 1;
                        }
                    }
                }
            },
            PartialAgg::IntSum { isums, fsums, overflowed, any } => {
                let (data, valid) = match &args[0] {
                    Column::Int64 { data, valid } => (data, valid.as_deref()),
                    other => bail!("SUM partial over {:?}", other.data_type()),
                };
                for (k, &g) in gids.iter().enumerate() {
                    let r = offset + k;
                    if valid.map_or(true, |v| v[r]) {
                        let g = g as usize;
                        any[g] = true;
                        if overflowed[g] {
                            fsums[g] += data[r] as f64;
                        } else {
                            match isums[g].checked_add(data[r]) {
                                Some(s) => isums[g] = s,
                                None => {
                                    overflowed[g] = true;
                                    fsums[g] = isums[g] as f64 + data[r] as f64;
                                }
                            }
                        }
                    }
                }
            }
            PartialAgg::FloatSum { sums, any } => {
                let (data, valid) = match &args[0] {
                    Column::Float64 { data, valid } => (data, valid.as_deref()),
                    other => bail!("SUM partial over {:?}", other.data_type()),
                };
                for (k, &g) in gids.iter().enumerate() {
                    let r = offset + k;
                    if valid.map_or(true, |v| v[r]) {
                        sums[g as usize] += data[r];
                        any[g as usize] = true;
                    }
                }
            }
            PartialAgg::NullAgg => {
                let what = if matches!(call.func, AggFunc::Sum) { "SUM" } else { "AVG" };
                let col = &args[0];
                for k in 0..gids.len() {
                    let r = offset + k;
                    if col.is_valid(r) {
                        bail!("{what} over non-numeric {}", col.value(r));
                    }
                }
            }
            PartialAgg::Avg { sums, counts } => match &args[0] {
                Column::Int64 { data, valid } => {
                    let valid = valid.as_deref();
                    for (k, &g) in gids.iter().enumerate() {
                        let r = offset + k;
                        if valid.map_or(true, |v| v[r]) {
                            sums[g as usize] += data[r] as f64;
                            counts[g as usize] += 1;
                        }
                    }
                }
                Column::Float64 { data, valid } => {
                    let valid = valid.as_deref();
                    for (k, &g) in gids.iter().enumerate() {
                        let r = offset + k;
                        if valid.map_or(true, |v| v[r]) {
                            sums[g as usize] += data[r];
                            counts[g as usize] += 1;
                        }
                    }
                }
                other => bail!("AVG partial over {:?}", other.data_type()),
            },
            PartialAgg::MinMax { best, is_min } => {
                let col = &args[0];
                let is_min = *is_min;
                for (k, &g) in gids.iter().enumerate() {
                    let r = offset + k;
                    if col.is_valid(r) {
                        let b = &mut best[g as usize];
                        if *b < 0 || min_max_better(col, r, *b as usize, is_min) {
                            *b = r as i64;
                        }
                    }
                }
            }
            PartialAgg::Udaf(states) => {
                let mut argv: Vec<Value> = Vec::with_capacity(args.len());
                for (k, &g) in gids.iter().enumerate() {
                    let r = offset + k;
                    argv.clear();
                    for c in args {
                        argv.push(c.value(r));
                    }
                    states[g as usize].update(&argv)?;
                }
            }
        }
        Ok(())
    }

    /// Fold `other` (a later morsel's partial over its local groups) into
    /// this global partial; local group `l` maps to global `map[l]`.
    /// Morsels merge in row-range order, so MIN/MAX ties keep the
    /// earliest row and UDAF states merge in scan order — exactly like
    /// the sequential pass. (Known caveat, mirroring the sequential
    /// scan's own quirk: a Float NaN compares as unknown and so "absorbs"
    /// every later candidate in its run; when a NaN leads a morsel, the
    /// absorbed span differs from the sequential scan's, so MIN/MAX over
    /// NaN-bearing floats can pick a different — equally NaN-shadowed —
    /// row.)
    fn merge(&mut self, other: PartialAgg, map: &[u32], args: &[Column]) -> Result<()> {
        match (self, other) {
            (PartialAgg::CountStar(g), PartialAgg::CountStar(l))
            | (PartialAgg::Count(g), PartialAgg::Count(l)) => {
                for (lg, c) in l.into_iter().enumerate() {
                    g[map[lg] as usize] += c;
                }
            }
            (
                PartialAgg::IntSum { isums, fsums, overflowed, any },
                PartialAgg::IntSum { isums: li, fsums: lf, overflowed: lo, any: la },
            ) => {
                for lg in 0..map.len() {
                    if !la[lg] {
                        continue;
                    }
                    let g = map[lg] as usize;
                    any[g] = true;
                    if overflowed[g] || lo[lg] {
                        let a = if overflowed[g] { fsums[g] } else { isums[g] as f64 };
                        let b = if lo[lg] { lf[lg] } else { li[lg] as f64 };
                        overflowed[g] = true;
                        fsums[g] = a + b;
                    } else {
                        match isums[g].checked_add(li[lg]) {
                            Some(s) => isums[g] = s,
                            None => {
                                overflowed[g] = true;
                                fsums[g] = isums[g] as f64 + li[lg] as f64;
                            }
                        }
                    }
                }
            }
            (PartialAgg::FloatSum { sums, any }, PartialAgg::FloatSum { sums: ls, any: la }) => {
                for lg in 0..map.len() {
                    if !la[lg] {
                        continue;
                    }
                    let g = map[lg] as usize;
                    sums[g] += ls[lg];
                    any[g] = true;
                }
            }
            (PartialAgg::NullAgg, PartialAgg::NullAgg) => {}
            (
                PartialAgg::Avg { sums, counts },
                PartialAgg::Avg { sums: ls, counts: lc },
            ) => {
                for lg in 0..map.len() {
                    if lc[lg] == 0 {
                        continue;
                    }
                    let g = map[lg] as usize;
                    sums[g] += ls[lg];
                    counts[g] += lc[lg];
                }
            }
            (PartialAgg::MinMax { best, is_min }, PartialAgg::MinMax { best: lb, .. }) => {
                let col = &args[0];
                for lg in 0..map.len() {
                    if lb[lg] < 0 {
                        continue;
                    }
                    let g = map[lg] as usize;
                    if best[g] < 0
                        || min_max_better(col, lb[lg] as usize, best[g] as usize, *is_min)
                    {
                        best[g] = lb[lg];
                    }
                }
            }
            (PartialAgg::Udaf(states), PartialAgg::Udaf(ls)) => {
                for (lg, s) in ls.into_iter().enumerate() {
                    states[map[lg] as usize].merge(s)?;
                }
            }
            _ => bail!("mismatched aggregate partial variants"),
        }
        Ok(())
    }

    /// Finish the merged partial into the output column, with the same
    /// type and validity derivation as the sequential grouped kernels.
    fn finish(
        self,
        call: &AggCall,
        args: &[Column],
        n_groups: usize,
        ctx: &ExecContext,
    ) -> Result<Column> {
        Ok(match self {
            PartialAgg::CountStar(counts) | PartialAgg::Count(counts) => {
                Column::from_i64(counts)
            }
            PartialAgg::IntSum { isums, fsums, overflowed, any } => {
                if !any.iter().any(|&a| a) {
                    null_f64_column(n_groups)
                } else if !overflowed.iter().any(|&o| o) {
                    Column::Int64 { data: isums, valid: mask_from_any(&any) }
                } else {
                    let data: Vec<f64> = (0..n_groups)
                        .map(|g| if overflowed[g] { fsums[g] } else { isums[g] as f64 })
                        .collect();
                    Column::Float64 { data, valid: mask_from_any(&any) }
                }
            }
            PartialAgg::FloatSum { sums, any } => {
                if !any.iter().any(|&a| a) {
                    null_f64_column(n_groups)
                } else {
                    Column::Float64 { data: sums, valid: mask_from_any(&any) }
                }
            }
            PartialAgg::NullAgg => null_f64_column(n_groups),
            PartialAgg::Avg { sums, counts } => {
                let data: Vec<f64> = sums
                    .iter()
                    .zip(&counts)
                    .map(|(s, &c)| if c > 0 { s / c as f64 } else { 0.0 })
                    .collect();
                let any: Vec<bool> = counts.iter().map(|&c| c > 0).collect();
                Column::Float64 { data, valid: mask_from_any(&any) }
            }
            PartialAgg::MinMax { best, .. } => {
                if best.iter().all(|&b| b < 0) {
                    null_f64_column(n_groups)
                } else {
                    args[0].gather_opt(&best)
                }
            }
            PartialAgg::Udaf(states) => {
                let udaf = ctx
                    .udfs
                    .udaf(&call.name)
                    .ok_or_else(|| anyhow!("no UDAF {:?}", call.name))?;
                let mut vals = Vec::with_capacity(n_groups);
                for s in &states {
                    vals.push(s.finish()?);
                }
                let mut dt = udaf.return_type;
                if dt == DataType::Int64 && vals.iter().any(|v| matches!(v, Value::Float(_))) {
                    dt = DataType::Float64;
                }
                Column::from_values(dt, &vals)?
            }
        })
    }
}

/// Morsel-parallel aggregation: every worker builds a thread-local
/// key-codec table (dense local group ids in first-seen order) plus
/// mergeable per-group partials for its contiguous row range; the merge
/// pass then re-keys local representatives into global dense ids — the
/// morsel-order walk reproduces the sequential first-seen group order —
/// and folds the partials (UDAF states fold through
/// [`UdafState::merge`]). Output matches `aggregate_vectorized` exactly,
/// up to float-summation re-association across morsel boundaries.
fn aggregate_parallel(
    rows: &RowSet,
    group: &[(Expr, String)],
    aggs: &[AggCall],
    key_cols: &[Column],
    arg_cols: &[Vec<Column>],
    ctx: &ExecContext,
    threads: usize,
) -> Result<RowSet> {
    struct MorselAgg {
        /// Global row index of each local group's first row.
        rep_rows: Vec<usize>,
        /// One partial per aggregate call.
        partials: Vec<PartialAgg>,
    }
    let n = rows.num_rows();
    let ranges = morsel_ranges(n, threads);
    let morsels: Vec<MorselAgg> = par_morsels(&ranges, |_, off, len| {
        let (gids, rep_rows, n_local) = if group.is_empty() {
            // Global aggregation: one group per (non-empty) morsel.
            (vec![0u32; len], Vec::new(), 1)
        } else {
            let mut dict = KeyDict::new();
            let keys = EncodedKeys::encode_range(key_cols, off, len, KeyMode::Group, &mut dict);
            let g = assign_group_ids(&keys);
            let n_local = g.n_groups();
            (g.ids, g.rep_rows.iter().map(|&r| r + off).collect(), n_local)
        };
        let partials = aggs
            .iter()
            .zip(arg_cols)
            .map(|(call, cols)| {
                let mut p = PartialAgg::empty(call, cols, n_local, ctx)?;
                p.update(call, cols, off, &gids)?;
                Ok(p)
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(MorselAgg { rep_rows, partials })
    })?;

    // Merge pass: assign global dense group ids over the morsels' local
    // representatives, walked in morsel order — which is exactly the
    // sequential first-seen order, because earlier morsels cover earlier
    // rows and a key's first morsel holds its first row.
    let (n_groups, group_maps, global_reps) = if group.is_empty() {
        (1usize, vec![vec![0u32]; morsels.len()], Vec::new())
    } else {
        let all_reps: Vec<usize> =
            morsels.iter().flat_map(|m| m.rep_rows.iter().copied()).collect();
        let rep_cols: Vec<Column> = key_cols.iter().map(|c| c.take(&all_reps)).collect();
        let mut dict = KeyDict::new();
        let keys = EncodedKeys::encode(&rep_cols, KeyMode::Group, &mut dict);
        let merged = assign_group_ids(&keys);
        let mut maps = Vec::with_capacity(morsels.len());
        let mut at = 0;
        for m in &morsels {
            maps.push(merged.ids[at..at + m.rep_rows.len()].to_vec());
            at += m.rep_rows.len();
        }
        let reps: Vec<usize> = merged.rep_rows.iter().map(|&p| all_reps[p]).collect();
        (merged.n_groups(), maps, reps)
    };

    let mut merged_partials: Vec<PartialAgg> = aggs
        .iter()
        .zip(arg_cols)
        .map(|(call, cols)| PartialAgg::empty(call, cols, n_groups, ctx))
        .collect::<Result<_>>()?;
    for (m, map) in morsels.into_iter().zip(&group_maps) {
        for ((global, local), cols) in merged_partials.iter_mut().zip(m.partials).zip(arg_cols) {
            global.merge(local, map, cols)?;
        }
    }

    let mut fields = Vec::with_capacity(group.len() + aggs.len());
    let mut columns = Vec::with_capacity(group.len() + aggs.len());
    for ((_, name), col) in group.iter().zip(key_cols) {
        let out = col.take(&global_reps);
        fields.push(Field::new(name.clone(), out.data_type()));
        columns.push(out);
    }
    for ((call, cols), partial) in aggs.iter().zip(arg_cols).zip(merged_partials) {
        let out = partial.finish(call, cols, n_groups, ctx)?;
        fields.push(Field::new(call.out_name.clone(), out.data_type()));
        columns.push(out);
    }
    RowSet::new(Schema::new(fields), columns)
}

/// Legacy row-at-a-time aggregation (kept for differential tests and the
/// codec on/off ablation).
fn aggregate_rowwise(
    rows: &RowSet,
    group: &[(Expr, String)],
    aggs: &[AggCall],
    key_cols: &[Column],
    arg_cols: &[Vec<Column>],
    ctx: &ExecContext,
) -> Result<RowSet> {
    let n = rows.num_rows();
    let mut groups: std::collections::HashMap<Vec<KeyValue>, GroupState> =
        std::collections::HashMap::new();
    // Preserve first-seen group order for deterministic output.
    let mut order: Vec<Vec<KeyValue>> = Vec::new();

    for r in 0..n {
        let key: Vec<KeyValue> = key_cols
            .iter()
            .map(|c| KeyValue::from_value(&c.value(r)))
            .collect();
        let state = match groups.get_mut(&key) {
            Some(s) => s,
            None => {
                let accs = aggs
                    .iter()
                    .map(|a| AggAcc::new(a, &ctx.udfs))
                    .collect::<Result<Vec<_>>>()?;
                let key_row = key_cols.iter().map(|c| c.value(r)).collect();
                order.push(key.clone());
                groups.insert(key.clone(), GroupState { key_row, accs });
                groups.get_mut(&key).unwrap()
            }
        };
        for (acc, cols) in state.accs.iter_mut().zip(arg_cols) {
            let args: Vec<Value> = cols.iter().map(|c| c.value(r)).collect();
            acc.update(&args)?;
        }
    }

    // Global aggregation over empty input still yields one row.
    if group.is_empty() && groups.is_empty() {
        let accs = aggs
            .iter()
            .map(|a| AggAcc::new(a, &ctx.udfs))
            .collect::<Result<Vec<_>>>()?;
        order.push(vec![]);
        groups.insert(vec![], GroupState { key_row: vec![], accs });
    }

    // Materialize output.
    let mut out_values: Vec<Vec<Value>> = Vec::with_capacity(order.len());
    for key in &order {
        let state = &groups[key];
        let mut row = state.key_row.clone();
        for acc in &state.accs {
            row.push(acc.finish()?);
        }
        out_values.push(row);
    }
    let mut fields = Vec::new();
    for ((_, name), col) in group.iter().zip(key_cols) {
        fields.push(Field::new(name.clone(), col.data_type()));
    }
    // Each aggregate's output type is computed once from its own output
    // column (the old code re-scanned `aggs` per produced row, which was
    // quadratic in the number of aggregates times groups).
    for (ai, a) in aggs.iter().enumerate() {
        let dt = match a.func {
            AggFunc::CountStar | AggFunc::Count => DataType::Int64,
            AggFunc::Avg => DataType::Float64,
            AggFunc::Sum | AggFunc::Min | AggFunc::Max => {
                // Derive from produced values; default Float64.
                out_values
                    .iter()
                    .find_map(|row| row[group.len() + ai].data_type())
                    .unwrap_or(DataType::Float64)
            }
            AggFunc::Udaf => ctx
                .udfs
                .udaf(&a.name)
                .map(|u| u.return_type)
                .unwrap_or(DataType::Float64),
        };
        fields.push(Field::new(a.out_name.clone(), dt));
    }
    let schema = Schema::new(fields);
    let n_cols = schema.len();
    let mut columns = Vec::with_capacity(n_cols);
    for c in 0..n_cols {
        let vals: Vec<Value> = out_values.iter().map(|r| r[c].clone()).collect();
        // Widen Int to Float if mixed (e.g. SUM overflow in some groups).
        let dt = if schema.field(c).data_type == DataType::Int64
            && vals.iter().any(|v| matches!(v, Value::Float(_)))
        {
            DataType::Float64
        } else {
            schema.field(c).data_type
        };
        columns.push(Column::from_values(dt, &vals)?);
    }
    let fields = schema
        .fields
        .iter()
        .zip(&columns)
        .map(|(f, c)| Field::new(f.name.clone(), c.data_type()))
        .collect();
    RowSet::new(Schema::new(fields), columns)
}

// --------------------------------------------------------------------- join

/// Build the combined schema for a join, qualifying colliding names.
fn join_schema(l: &RowSet, lalias: &str, r: &RowSet, ralias: &str) -> Schema {
    let mut fields = Vec::new();
    let collides = |name: &str| {
        l.schema.index_of(name).is_some() && r.schema.index_of(name).is_some()
    };
    for f in &l.schema.fields {
        let name = if collides(&f.name) {
            format!("{lalias}.{}", f.name)
        } else {
            f.name.clone()
        };
        fields.push(Field::new(name, f.data_type));
    }
    for f in &r.schema.fields {
        let name = if collides(&f.name) {
            format!("{ralias}.{}", f.name)
        } else {
            f.name.clone()
        };
        fields.push(Field::new(name, f.data_type));
    }
    Schema::new(fields)
}

fn plan_alias(p: &Plan, default: &str) -> String {
    match p {
        Plan::Scan { table, alias } => alias.clone().unwrap_or_else(|| table.clone()),
        Plan::TableFunc { name, alias, .. } => alias.clone().unwrap_or_else(|| name.clone()),
        Plan::Filter { input, .. } | Plan::Limit { input, .. } | Plan::Sort { input, .. } => {
            plan_alias(input, default)
        }
        _ => default.to_string(),
    }
}

/// Hash join (equi) with optional residual filter; falls back to a
/// nested-loop cross product + filter when no equi keys exist. The
/// vectorized path builds its table from codec-encoded keys and probes
/// with `&[u8]` compares; both paths emit `l_idx`/`r_idx` gather vectors
/// that materialize through typed column gathers.
fn join(
    l: &RowSet,
    r: &RowSet,
    kind: JoinKind,
    equi: &[(Expr, Expr)],
    residual: Option<&Expr>,
    ctx: &ExecContext,
    plan: &Plan,
) -> Result<RowSet> {
    let (lalias, ralias) = match plan {
        Plan::Join { left, right, .. } => {
            (plan_alias(left, "l"), plan_alias(right, "r"))
        }
        _ => ("l".to_string(), "r".to_string()),
    };
    let out_schema = join_schema(l, &lalias, r, &ralias);

    // Assign each equi pair's sides: an expression belongs to the side
    // whose schema resolves all its columns.
    let resolvable = |e: &Expr, rs: &RowSet| -> bool {
        let mut cols = Vec::new();
        e.referenced_columns(&mut cols);
        !cols.is_empty() && cols.iter().all(|c| resolve_column(&rs.schema, c).is_ok())
    };
    let mut lkeys: Vec<&Expr> = Vec::new();
    let mut rkeys: Vec<&Expr> = Vec::new();
    for (a, b) in equi {
        if resolvable(a, l) && resolvable(b, r) {
            lkeys.push(a);
            rkeys.push(b);
        } else if resolvable(b, l) && resolvable(a, r) {
            lkeys.push(b);
            rkeys.push(a);
        } else {
            bail!(
                "cannot assign join condition {} = {} to sides",
                a.to_sql(),
                b.to_sql()
            );
        }
    }

    let mut l_idx: Vec<i64> = Vec::new();
    let mut r_idx: Vec<i64> = Vec::new(); // -1 = NULL row (left join)

    if lkeys.is_empty() {
        // Cross product (small inputs only — residual filters after).
        for i in 0..l.num_rows() {
            let mut matched = false;
            for j in 0..r.num_rows() {
                l_idx.push(i as i64);
                r_idx.push(j as i64);
                matched = true;
            }
            if !matched && kind == JoinKind::Left {
                l_idx.push(i as i64);
                r_idx.push(-1);
            }
        }
    } else {
        let rkey_cols: Vec<Column> = rkeys
            .iter()
            .map(|e| eval(e, r, ctx))
            .collect::<Result<_>>()?;
        let lkey_cols: Vec<Column> = lkeys
            .iter()
            .map(|e| eval(e, l, ctx))
            .collect::<Result<_>>()?;
        if ctx.vectorized {
            // One shared dict so equal strings on both sides intern to
            // equal ids; one hash per row, zero key clones.
            let mut dict = KeyDict::new();
            let build_keys = EncodedKeys::encode(&rkey_cols, KeyMode::Join, &mut dict);
            let probe_keys = EncodedKeys::encode(&lkey_cols, KeyMode::Join, &mut dict);
            // Build the shared table, hash-partitioned across workers
            // when the build side is large: one O(n) pass routes each
            // non-NULL build row to its partition, then the sub-tables
            // build concurrently from their (ascending) row lists. Equal
            // keys share a hash, so every partition owns all rows of its
            // keys and the combined table behaves exactly like a
            // single-table build.
            let n_parts = parallel_threads(r.num_rows(), ctx);
            let parts: Vec<JoinTable> = if n_parts > 1 {
                let mut part_rows: Vec<Vec<u32>> = vec![Vec::new(); n_parts];
                for row in 0..build_keys.len() {
                    if !build_keys.has_null(row) {
                        part_rows[super::hash::join_partition(build_keys.hash(row), n_parts)]
                            .push(row as u32);
                    }
                }
                let bk = &build_keys;
                std::thread::scope(|s| {
                    let handles: Vec<_> = part_rows
                        .into_iter()
                        .map(|rows| s.spawn(move || JoinTable::build_from_rows(bk, rows)))
                        .collect();
                    handles
                        .into_iter()
                        .map(|h| h.join().unwrap_or_else(|e| std::panic::resume_unwind(e)))
                        .collect()
                })
            } else {
                vec![JoinTable::build(&build_keys)]
            };
            let table = PartitionedJoinTable::from_parts(parts);
            // Probe in row order; per-row match enumeration is what the
            // sequential loop does, so per-morsel output segments
            // concatenate to the identical (l_idx, r_idx) sequence.
            let probe_row = |i: usize, li: &mut Vec<i64>, ri: &mut Vec<i64>| {
                let mut matched = false;
                if !probe_keys.has_null(i) {
                    // SQL join: NULL keys never match.
                    for j in table.matches(probe_keys.key(i), probe_keys.hash(i)) {
                        li.push(i as i64);
                        ri.push(j as i64);
                        matched = true;
                    }
                }
                if !matched && kind == JoinKind::Left {
                    li.push(i as i64);
                    ri.push(-1);
                }
            };
            let probe_threads = parallel_threads(l.num_rows(), ctx);
            if probe_threads > 1 {
                let ranges = morsel_ranges(l.num_rows(), probe_threads);
                let segments = par_morsels(&ranges, |_, off, len| {
                    let mut li = Vec::new();
                    let mut ri = Vec::new();
                    for i in off..off + len {
                        probe_row(i, &mut li, &mut ri);
                    }
                    Ok((li, ri))
                })?;
                for (li, ri) in segments {
                    l_idx.extend_from_slice(&li);
                    r_idx.extend_from_slice(&ri);
                }
            } else {
                for i in 0..l.num_rows() {
                    probe_row(i, &mut l_idx, &mut r_idx);
                }
            }
        } else {
            // Legacy path: per-row KeyValue materialization.
            let mut table: std::collections::HashMap<Vec<KeyValue>, Vec<usize>> =
                std::collections::HashMap::new();
            for j in 0..r.num_rows() {
                let key: Vec<KeyValue> = rkey_cols
                    .iter()
                    .map(|c| KeyValue::join_normalized(&c.value(j)))
                    .collect();
                // SQL join: NULL keys never match.
                if key.iter().any(|k| matches!(k, KeyValue::Null)) {
                    continue;
                }
                table.entry(key).or_default().push(j);
            }
            for i in 0..l.num_rows() {
                let key: Vec<KeyValue> = lkey_cols
                    .iter()
                    .map(|c| KeyValue::join_normalized(&c.value(i)))
                    .collect();
                let matches = if key.iter().any(|k| matches!(k, KeyValue::Null)) {
                    None
                } else {
                    table.get(&key)
                };
                match matches {
                    Some(js) => {
                        for &j in js {
                            l_idx.push(i as i64);
                            r_idx.push(j as i64);
                        }
                    }
                    None => {
                        if kind == JoinKind::Left {
                            l_idx.push(i as i64);
                            r_idx.push(-1);
                        }
                    }
                }
            }
        }
    }

    // Residual predicate, evaluated BEFORE materialization: only the
    // columns the predicate references are gathered through the
    // `l_idx`/`r_idx` vectors, the mask compacts the index vectors, and
    // rows the residual drops are never gathered into the wide output.
    // (Left-join NULL-row preservation caveat as before: a left row whose
    // every match fails the residual is dropped, not re-NULL-padded.)
    let (l_idx, r_idx) = match residual {
        Some(pred) => {
            let mask = residual_mask(pred, l, r, &out_schema, &l_idx, &r_idx, ctx)?;
            let mut fl = Vec::with_capacity(l_idx.len());
            let mut fr = Vec::with_capacity(r_idx.len());
            for (k, keep) in mask.iter().enumerate() {
                if *keep {
                    fl.push(l_idx[k]);
                    fr.push(r_idx[k]);
                }
            }
            (fl, fr)
        }
        None => (l_idx, r_idx),
    };

    // Materialize the combined rowset through typed gathers.
    materialize_join(l, r, &out_schema, &l_idx, &r_idx, ctx)
}

/// Evaluate a residual join predicate over the gather vectors without
/// materializing the full combined rowset: resolve the predicate's
/// referenced columns against the combined schema, gather only those,
/// and return the keep-mask over the candidate matches.
fn residual_mask(
    pred: &Expr,
    l: &RowSet,
    r: &RowSet,
    out_schema: &Schema,
    l_idx: &[i64],
    r_idx: &[i64],
    ctx: &ExecContext,
) -> Result<Vec<bool>> {
    let mut names = Vec::new();
    pred.referenced_columns(&mut names);
    let mut needed: Vec<usize> = names
        .iter()
        .map(|n| resolve_column(out_schema, n))
        .collect::<Result<_>>()?;
    needed.sort_unstable();
    needed.dedup();
    let ln = l.num_columns();
    let mut fields = Vec::with_capacity(needed.len().max(1));
    let mut cols = Vec::with_capacity(needed.len().max(1));
    if needed.is_empty() {
        // Column-free residual (e.g. a constant conjunct): a zero-column
        // rowset would report zero rows, so carry a dummy column that
        // pins the row count to the number of candidate matches.
        fields.push(Field::new("__residual_dummy", DataType::Int64));
        cols.push(Column::from_i64(vec![0; l_idx.len()]));
    }
    for &ci in &needed {
        fields.push(out_schema.field(ci).clone());
        let col = if ci < ln {
            l.column(ci).gather_opt(l_idx)
        } else {
            r.column(ci - ln).gather_opt(r_idx)
        };
        cols.push(col);
    }
    let narrow = RowSet::new(Schema::new(fields), cols)?;
    eval_pred(pred, &narrow, ctx)
}

fn materialize_join(
    l: &RowSet,
    r: &RowSet,
    schema: &Schema,
    l_idx: &[i64],
    r_idx: &[i64],
    ctx: &ExecContext,
) -> Result<RowSet> {
    let ln = l.num_columns();
    let n_cols = ln + r.num_columns();
    let threads = parallel_threads(l_idx.len(), ctx).min(n_cols);
    if threads > 1 && n_cols > 1 {
        // Wide outputs gather concurrently: columns chunk across at most
        // `ctx.parallelism` workers; each per-column gather is unchanged,
        // so the rowset is identical.
        let gather_col = |ci: usize| {
            if ci < ln {
                l.column(ci).gather_opt(l_idx)
            } else {
                r.column(ci - ln).gather_opt(r_idx)
            }
        };
        let chunks = par_morsels(&morsel_ranges(n_cols, threads), |_, off, len| {
            Ok((off..off + len).map(|ci| gather_col(ci)).collect::<Vec<Column>>())
        })?;
        let columns: Vec<Column> = chunks.into_iter().flatten().collect();
        return RowSet::new(schema.clone(), columns);
    }
    let left = l.gather(l_idx, false);
    let right = r.gather(r_idx, true); // -1 = NULL row (unmatched left rows)
    let mut columns = left.columns;
    columns.extend(right.columns);
    RowSet::new(schema.clone(), columns)
}

// --------------------------------------------------------------------- sort

/// A decorated sort key: raw typed slice + validity + direction, computed
/// once so the comparator never materializes a `Value` (or clones a
/// string) per comparison.
enum SortVals<'a> {
    I64(&'a [i64]),
    F64(&'a [f64]),
    Str(&'a [String]),
    Bool(&'a [bool]),
}

struct SortKeyCol<'a> {
    vals: SortVals<'a>,
    valid: Option<&'a [bool]>,
    descending: bool,
}

fn decorate<'a>(keys: &[OrderKey], cols: &'a [Column]) -> Vec<SortKeyCol<'a>> {
    keys.iter()
        .zip(cols)
        .map(|(k, c)| {
            let vals = match c {
                Column::Int64 { data, .. } => SortVals::I64(data),
                Column::Float64 { data, .. } => SortVals::F64(data),
                Column::Utf8 { data, .. } => SortVals::Str(data),
                Column::Bool { data, .. } => SortVals::Bool(data),
            };
            SortKeyCol { vals, valid: c.validity(), descending: k.descending }
        })
        .collect()
}

fn cmp_decorated(keys: &[SortKeyCol], a: usize, b: usize) -> Ordering {
    for k in keys {
        let na = k.valid.map_or(false, |v| !v[a]);
        let nb = k.valid.map_or(false, |v| !v[b]);
        // NULLS LAST in ascending order.
        let ord = match (na, nb) {
            (true, true) => Ordering::Equal,
            (true, false) => Ordering::Greater,
            (false, true) => Ordering::Less,
            (false, false) => match &k.vals {
                SortVals::I64(d) => d[a].cmp(&d[b]),
                SortVals::F64(d) => d[a].partial_cmp(&d[b]).unwrap_or(Ordering::Equal),
                SortVals::Str(d) => d[a].cmp(&d[b]),
                SortVals::Bool(d) => d[a].cmp(&d[b]),
            },
        };
        let ord = if k.descending { ord.reverse() } else { ord };
        if ord != Ordering::Equal {
            return ord;
        }
    }
    Ordering::Equal
}

/// Legacy comparator over scalar `Value`s (row-at-a-time path).
fn cmp_values(keys: &[OrderKey], cols: &[Column], a: usize, b: usize) -> Ordering {
    for (k, col) in keys.iter().zip(cols) {
        let va = col.value(a);
        let vb = col.value(b);
        // NULLS LAST in ascending order.
        let ord = match (va.is_null(), vb.is_null()) {
            (true, true) => Ordering::Equal,
            (true, false) => Ordering::Greater,
            (false, true) => Ordering::Less,
            (false, false) => va.sql_cmp(&vb).unwrap_or(Ordering::Equal),
        };
        let ord = if k.descending { ord.reverse() } else { ord };
        if ord != Ordering::Equal {
            return ord;
        }
    }
    Ordering::Equal
}

/// Order `idx` by `cmp`; with a limit, partition the top `k` first
/// (`select_nth_unstable_by`) and only sort that prefix.
fn apply_order<F: FnMut(&usize, &usize) -> Ordering>(
    idx: &mut Vec<usize>,
    limit: Option<usize>,
    cmp: &mut F,
) {
    match limit {
        Some(0) => idx.clear(),
        Some(k) if k < idx.len() => {
            let _ = idx.select_nth_unstable_by(k - 1, &mut *cmp);
            idx[..k].sort_unstable_by(&mut *cmp);
            idx.truncate(k);
        }
        _ => idx.sort_unstable_by(&mut *cmp),
    }
}

/// Merge per-morsel sorted runs under the strict total order `cmp`,
/// optionally stopping after `limit` outputs. Because the order is total
/// (index tiebreak — no two rows compare equal), the merged sequence is
/// the unique globally sorted order, and per-run top-k truncation cannot
/// drop a global top-k row.
fn kway_merge<F: Fn(usize, usize) -> Ordering>(
    runs: Vec<Vec<usize>>,
    limit: Option<usize>,
    cmp: F,
) -> Vec<usize> {
    let total: usize = runs.iter().map(Vec::len).sum();
    let want = limit.map_or(total, |k| k.min(total));
    let mut pos = vec![0usize; runs.len()];
    let mut out = Vec::with_capacity(want);
    while out.len() < want {
        // Linear scan over run heads: the run count is the worker-thread
        // count, so a heap would not pay for itself.
        let mut best: Option<usize> = None;
        for (ri, run) in runs.iter().enumerate() {
            if pos[ri] >= run.len() {
                continue;
            }
            best = match best {
                Some(b) if cmp(run[pos[ri]], runs[b][pos[b]]) != Ordering::Less => Some(b),
                _ => Some(ri),
            };
        }
        let b = best.expect("runs exhausted before limit");
        out.push(runs[b][pos[b]]);
        pos[b] += 1;
    }
    out
}

/// Sort (optionally top-k when `limit` is set). Sort keys are decorated
/// once — typed slices + validity — instead of materializing two `Value`s
/// per comparison. The comparator is a strict total order (index
/// tiebreak), so top-k output is identical to sort-then-limit. Large
/// inputs sort as per-morsel runs on worker threads (each run top-k
/// truncated when a limit is set) followed by a k-way merge; the total
/// order makes the result identical to the sequential sort at any thread
/// count.
fn sort(
    rows: &RowSet,
    keys: &[OrderKey],
    ctx: &ExecContext,
    limit: Option<usize>,
) -> Result<RowSet> {
    let key_cols: Vec<Column> = keys
        .iter()
        .map(|k| eval(&k.expr, rows, ctx))
        .collect::<Result<_>>()?;
    let n = rows.num_rows();
    if ctx.vectorized {
        let dk = decorate(keys, &key_cols);
        let cmp = |a: usize, b: usize| cmp_decorated(&dk, a, b).then_with(|| a.cmp(&b));
        let threads = parallel_threads(n, ctx);
        let idx = if threads > 1 && limit != Some(0) {
            let runs = par_morsels(&morsel_ranges(n, threads), |_, off, len| {
                let mut run: Vec<usize> = (off..off + len).collect();
                let mut c = |a: &usize, b: &usize| cmp(*a, *b);
                apply_order(&mut run, limit, &mut c);
                Ok(run)
            })?;
            kway_merge(runs, limit, cmp)
        } else {
            let mut idx: Vec<usize> = (0..n).collect();
            let mut c = |a: &usize, b: &usize| cmp(*a, *b);
            apply_order(&mut idx, limit, &mut c);
            idx
        };
        Ok(rows.take(&idx))
    } else {
        let mut idx: Vec<usize> = (0..n).collect();
        let mut cmp =
            |a: &usize, b: &usize| cmp_values(keys, &key_cols, *a, *b).then_with(|| a.cmp(b));
        apply_order(&mut idx, limit, &mut cmp);
        Ok(rows.take(&idx))
    }
}

/// Convenience: parse, plan, and execute a SQL string.
pub fn run_sql(sql: &str, ctx: &ExecContext) -> Result<RowSet> {
    Ok(run_sql_with_stats(sql, ctx)?.0)
}

/// Like [`run_sql`], also returning per-operator rows and timings.
pub fn run_sql_with_stats(sql: &str, ctx: &ExecContext) -> Result<(RowSet, QueryStats)> {
    let q = crate::sql::parse_query(sql)?;
    let plan = super::plan::plan_query(&q, &ctx.udfs)?;
    execute_plan_with_stats(&plan, ctx)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> ExecContext {
        let catalog = Arc::new(Catalog::new());
        let sales = RowSet::new(
            Schema::new(vec![
                Field::new("id", DataType::Int64),
                Field::new("cat", DataType::Utf8),
                Field::new("price", DataType::Float64),
                Field::new("qty", DataType::Int64),
            ]),
            vec![
                Column::from_i64(vec![1, 2, 3, 4, 5]),
                Column::from_strings(
                    ["a", "b", "a", "b", "a"].iter().map(|s| s.to_string()).collect(),
                ),
                Column::from_f64(vec![10.0, 20.0, 30.0, 40.0, 50.0]),
                Column::from_i64(vec![1, 2, 3, 4, 5]),
            ],
        )
        .unwrap();
        catalog.register("sales", sales);
        let cats = RowSet::new(
            Schema::new(vec![
                Field::new("cat", DataType::Utf8),
                Field::new("label", DataType::Utf8),
            ]),
            vec![
                Column::from_strings(vec!["a".into(), "c".into()]),
                Column::from_strings(vec!["alpha".into(), "gamma".into()]),
            ],
        )
        .unwrap();
        catalog.register("cats", cats);
        ExecContext::new(catalog, Arc::new(UdfRegistry::new()))
    }

    fn sql(s: &str) -> RowSet {
        run_sql(s, &ctx()).unwrap_or_else(|e| panic!("{s}: {e}"))
    }

    /// Same statement through the codec and the legacy row path.
    fn sql_both(s: &str) -> (RowSet, RowSet) {
        let vectorized = run_sql(s, &ctx()).unwrap_or_else(|e| panic!("{s}: {e}"));
        let rowwise = run_sql(s, &ctx().with_vectorized(false))
            .unwrap_or_else(|e| panic!("{s} (rowwise): {e}"));
        (vectorized, rowwise)
    }

    #[test]
    fn scan_filter_project() {
        let rs = sql("SELECT id, price * qty AS total FROM sales WHERE price > 15");
        assert_eq!(rs.num_rows(), 4);
        assert_eq!(rs.schema.names(), vec!["id", "total"]);
        assert_eq!(rs.row(0), vec![Value::Int(2), Value::Float(40.0)]);
    }

    #[test]
    fn select_star() {
        let rs = sql("SELECT * FROM sales LIMIT 2");
        assert_eq!(rs.num_rows(), 2);
        assert_eq!(rs.num_columns(), 4);
    }

    #[test]
    fn group_by_and_having() {
        let rs = sql(
            "SELECT cat, COUNT(*) AS n, SUM(price) AS total, AVG(qty) AS avg_q \
             FROM sales GROUP BY cat ORDER BY cat",
        );
        assert_eq!(rs.num_rows(), 2);
        assert_eq!(
            rs.row(0),
            vec![
                Value::Str("a".into()),
                Value::Int(3),
                Value::Float(90.0),
                Value::Float(3.0)
            ]
        );
        let rs = sql("SELECT cat FROM sales GROUP BY cat HAVING SUM(price) > 80 ORDER BY cat");
        assert_eq!(rs.num_rows(), 1);
        assert_eq!(rs.row(0)[0], Value::Str("a".into()));
    }

    #[test]
    fn global_aggregate_empty_input() {
        let rs = sql("SELECT COUNT(*) AS n, SUM(price) AS s FROM sales WHERE price > 999");
        assert_eq!(rs.num_rows(), 1);
        assert_eq!(rs.row(0), vec![Value::Int(0), Value::Null]);
    }

    #[test]
    fn min_max_and_expression_aggregates() {
        let rs = sql("SELECT MIN(price) AS lo, MAX(price * qty) AS hi FROM sales");
        assert_eq!(rs.row(0), vec![Value::Float(10.0), Value::Float(250.0)]);
    }

    #[test]
    fn inner_join() {
        let rs = sql(
            "SELECT s.id, c.label FROM sales s JOIN cats c ON s.cat = c.cat ORDER BY s.id",
        );
        assert_eq!(rs.num_rows(), 3); // only cat 'a' matches
        assert_eq!(rs.row(0), vec![Value::Int(1), Value::Str("alpha".into())]);
    }

    #[test]
    fn left_join_preserves_unmatched() {
        let rs = sql(
            "SELECT s.id, c.label FROM sales s LEFT JOIN cats c ON s.cat = c.cat ORDER BY s.id",
        );
        assert_eq!(rs.num_rows(), 5);
        assert_eq!(rs.row(1), vec![Value::Int(2), Value::Null]); // cat 'b'
    }

    #[test]
    fn join_with_residual() {
        let rs = sql(
            "SELECT s.id FROM sales s JOIN cats c ON s.cat = c.cat AND s.price > 25 ORDER BY s.id",
        );
        assert_eq!(rs.num_rows(), 2); // ids 3, 5
    }

    #[test]
    fn colliding_join_columns_get_qualified() {
        let rs = sql("SELECT s.cat, c.cat FROM sales s JOIN cats c ON s.cat = c.cat LIMIT 1");
        assert_eq!(rs.num_columns(), 2);
    }

    #[test]
    fn order_by_desc_and_nulls() {
        let rs = sql("SELECT id FROM sales ORDER BY price DESC LIMIT 2");
        assert_eq!(rs.row(0)[0], Value::Int(5));
        assert_eq!(rs.row(1)[0], Value::Int(4));
    }

    #[test]
    fn order_by_alias() {
        let rs = sql("SELECT id, price * qty AS total FROM sales ORDER BY total DESC LIMIT 1");
        assert_eq!(rs.row(0)[0], Value::Int(5));
    }

    #[test]
    fn subquery_pipeline() {
        let rs = sql(
            "SELECT cat, n FROM (SELECT cat, COUNT(*) AS n FROM sales GROUP BY cat) t \
             WHERE n > 2",
        );
        assert_eq!(rs.num_rows(), 1);
        assert_eq!(rs.row(0)[0], Value::Str("a".into()));
    }

    #[test]
    fn select_without_from() {
        let rs = sql("SELECT 1 + 1 AS two");
        assert_eq!(rs.num_rows(), 1);
        assert_eq!(rs.row(0)[0], Value::Int(2));
    }

    #[test]
    fn case_in_group_by() {
        let rs = sql(
            "SELECT CASE WHEN price > 25 THEN 'hi' ELSE 'lo' END AS band, COUNT(*) AS n \
             FROM sales GROUP BY CASE WHEN price > 25 THEN 'hi' ELSE 'lo' END ORDER BY band",
        );
        assert_eq!(rs.num_rows(), 2);
        assert_eq!(rs.row(0), vec![Value::Str("hi".into()), Value::Int(3)]);
    }

    #[test]
    fn limit_zero_and_overrun() {
        assert_eq!(sql("SELECT * FROM sales LIMIT 0").num_rows(), 0);
        assert_eq!(sql("SELECT * FROM sales LIMIT 99").num_rows(), 5);
    }

    #[test]
    fn codec_and_rowwise_paths_agree() {
        for q in [
            "SELECT cat, COUNT(*) AS n, SUM(price) AS s, AVG(qty) AS a, MIN(price) AS lo, \
             MAX(price) AS hi FROM sales GROUP BY cat",
            "SELECT qty, COUNT(*) AS n FROM sales GROUP BY qty",
            "SELECT s.id, c.label FROM sales s JOIN cats c ON s.cat = c.cat",
            "SELECT s.id, c.label FROM sales s LEFT JOIN cats c ON s.cat = c.cat",
            "SELECT id, cat FROM sales ORDER BY cat, price DESC",
            "SELECT id FROM sales ORDER BY price DESC LIMIT 3",
        ] {
            let (vectorized, rowwise) = sql_both(q);
            assert_eq!(vectorized, rowwise, "{q}");
        }
    }

    #[test]
    fn sum_int_keeps_i64_precision() {
        // 2^53 + 1 is not representable in f64: the old f64 accumulator
        // silently rounded it.
        let catalog = Arc::new(Catalog::new());
        let big = (1i64 << 53) + 1;
        let t = RowSet::new(
            Schema::new(vec![Field::new("x", DataType::Int64)]),
            vec![Column::from_i64(vec![big, 0])],
        )
        .unwrap();
        catalog.register("t", t);
        for vectorized in [true, false] {
            let c = ExecContext::new(catalog.clone(), Arc::new(UdfRegistry::new()))
                .with_vectorized(vectorized);
            let rs = run_sql("SELECT SUM(x) AS s FROM t", &c).unwrap();
            assert_eq!(rs.row(0)[0], Value::Int(big), "vectorized={vectorized}");
        }
    }

    #[test]
    fn sum_int_overflow_widens_to_float() {
        let catalog = Arc::new(Catalog::new());
        let t = RowSet::new(
            Schema::new(vec![Field::new("x", DataType::Int64)]),
            vec![Column::from_i64(vec![i64::MAX, i64::MAX])],
        )
        .unwrap();
        catalog.register("t", t);
        for vectorized in [true, false] {
            let c = ExecContext::new(catalog.clone(), Arc::new(UdfRegistry::new()))
                .with_vectorized(vectorized);
            let rs = run_sql("SELECT SUM(x) AS s FROM t", &c).unwrap();
            let got = rs.row(0)[0].as_f64().unwrap();
            let want = i64::MAX as f64 * 2.0;
            assert!((got - want).abs() / want < 1e-12, "vectorized={vectorized}: {got}");
        }
    }

    #[test]
    fn top_k_matches_full_sort() {
        let rs_k = sql("SELECT id FROM sales ORDER BY price DESC, id LIMIT 2");
        assert_eq!(rs_k.num_rows(), 2);
        assert_eq!(rs_k.row(0)[0], Value::Int(5));
        assert_eq!(rs_k.row(1)[0], Value::Int(4));
        // Hidden sort key (ORDER BY column not in the select list) also
        // takes the top-k path through the planner's projection.
        let rs_h = sql("SELECT cat FROM sales ORDER BY price DESC LIMIT 1");
        assert_eq!(rs_h.row(0)[0], Value::Str("a".into()));
        assert_eq!(rs_h.schema.names(), vec!["cat"]);
    }

    #[test]
    fn query_stats_observe_operators() {
        let (out, stats) =
            run_sql_with_stats("SELECT cat, COUNT(*) AS n FROM sales GROUP BY cat", &ctx())
                .unwrap();
        assert_eq!(stats.rows_scanned, 5);
        assert_eq!(stats.rows_output, out.num_rows() as u64);
        assert_eq!(stats.aggregate.invocations, 1);
        assert_eq!(stats.aggregate.rows_in, 5);
        assert_eq!(stats.aggregate.rows_out, 2);
        let report = stats.report();
        assert!(report.contains("aggregate"), "{report}");
    }

    #[test]
    fn scalar_udf_in_query() {
        let c = ctx();
        let mut udfs = UdfRegistry::new();
        udfs.register_scalar(
            "add_tax",
            DataType::Float64,
            Arc::new(|args| {
                Ok(Value::Float(args[0].as_f64().unwrap_or(0.0) * 1.1))
            }),
        );
        let c = ExecContext::new(c.catalog, Arc::new(udfs));
        let rs = run_sql("SELECT add_tax(price) AS p FROM sales WHERE id = 1", &c).unwrap();
        assert_eq!(rs.row(0)[0], Value::Float(11.0));
    }

    #[test]
    fn udaf_in_query() {
        let c = ctx();
        let mut udfs = UdfRegistry::new();
        // Geometric-mean UDAF.
        struct Geo {
            log_sum: f64,
            n: i64,
        }
        impl crate::udf::UdafState for Geo {
            fn update(&mut self, args: &[Value]) -> Result<()> {
                if let Some(x) = args[0].as_f64() {
                    if x > 0.0 {
                        self.log_sum += x.ln();
                        self.n += 1;
                    }
                }
                Ok(())
            }
            fn merge(&mut self, other: Box<dyn crate::udf::UdafState>) -> Result<()> {
                let o = other.as_any().downcast_ref::<Geo>().unwrap();
                self.log_sum += o.log_sum;
                self.n += o.n;
                Ok(())
            }
            fn finish(&self) -> Result<Value> {
                if self.n == 0 {
                    Ok(Value::Null)
                } else {
                    Ok(Value::Float((self.log_sum / self.n as f64).exp()))
                }
            }
            fn as_any(&self) -> &dyn std::any::Any {
                self
            }
        }
        udfs.register_udaf(
            "geomean",
            DataType::Float64,
            Arc::new(|| Box::new(Geo { log_sum: 0.0, n: 0 })),
        );
        let c = ExecContext::new(c.catalog, Arc::new(udfs));
        let rs = run_sql("SELECT geomean(price) AS g FROM sales", &c).unwrap();
        let g = rs.row(0)[0].as_f64().unwrap();
        let want = (10f64 * 20.0 * 30.0 * 40.0 * 50.0).powf(0.2);
        assert!((g - want).abs() < 1e-9, "{g} vs {want}");
    }

    #[test]
    fn morsel_ranges_cover_input() {
        for (n, t) in [(10usize, 3usize), (4096, 1), (100_000, 8), (5, 9)] {
            let ranges = morsel_ranges(n, t);
            assert_eq!(ranges.iter().map(|&(_, len)| len).sum::<usize>(), n);
            let mut off = 0;
            for &(o, len) in &ranges {
                assert_eq!(o, off, "n={n} t={t}");
                assert!(len > 0, "n={n} t={t}: empty morsel");
                off += len;
            }
        }
    }

    /// A table big enough that parallelism 8 splits into several morsels
    /// (40 000 / MORSEL_MIN_ROWS ≥ 8). Values are quarter-integers so
    /// float sums are exact and parallel aggregation is byte-identical.
    fn big_catalog() -> Arc<Catalog> {
        let catalog = Arc::new(Catalog::new());
        let n = 40_000usize;
        let mut state = 0x9e37_79b9_7f4a_7c15u64;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        let keys: Vec<i64> = (0..n).map(|_| (next() % 300) as i64).collect();
        let vals: Vec<f64> = (0..n).map(|_| (next() % 2000) as f64 / 4.0).collect();
        let vmask: Vec<bool> = (0..n).map(|_| next() % 10 != 0).collect();
        let tags: Vec<String> = keys.iter().map(|k| format!("t{:02}", k % 40)).collect();
        let facts = RowSet::new(
            Schema::new(vec![
                Field::new("k", DataType::Int64),
                Field::new("v", DataType::Float64),
                Field::new("tag", DataType::Utf8),
            ]),
            vec![
                Column::from_i64(keys),
                Column::Float64 { data: vals, valid: Some(vmask) },
                Column::from_strings(tags),
            ],
        )
        .unwrap();
        catalog.register("facts", facts);
        let dim = RowSet::new(
            Schema::new(vec![
                Field::new("k", DataType::Int64),
                Field::new("label", DataType::Utf8),
            ]),
            vec![
                Column::from_i64((0..200i64).collect()),
                Column::from_strings((0..200).map(|k| format!("label_{k}")).collect()),
            ],
        )
        .unwrap();
        catalog.register("dim", dim);
        catalog
    }

    #[test]
    fn parallel_operators_match_sequential() {
        let catalog = big_catalog();
        for q in [
            "SELECT k, COUNT(*) AS n, COUNT(v) AS nv, SUM(v) AS s, AVG(v) AS a, \
             MIN(v) AS lo, MAX(tag) AS hi FROM facts GROUP BY k",
            "SELECT tag, SUM(k) AS s FROM facts WHERE v > 100.0 GROUP BY tag",
            "SELECT COUNT(*) AS n, SUM(v) AS s FROM facts",
            "SELECT facts.k, label FROM facts JOIN dim ON facts.k = dim.k AND v > 400.0",
            "SELECT facts.k, label FROM facts LEFT JOIN dim ON facts.k = dim.k",
            "SELECT k, v FROM facts ORDER BY v DESC, k",
            "SELECT k, v FROM facts ORDER BY tag, v LIMIT 37",
            "SELECT k + 1 AS k1, v * 2.0 AS v2 FROM facts WHERE k < 250",
        ] {
            let seq = run_sql(
                q,
                &ExecContext::new(catalog.clone(), Arc::new(UdfRegistry::new()))
                    .with_parallelism(1),
            )
            .unwrap_or_else(|e| panic!("{q}: {e}"));
            for p in [2usize, 8] {
                let par = run_sql(
                    q,
                    &ExecContext::new(catalog.clone(), Arc::new(UdfRegistry::new()))
                        .with_parallelism(p),
                )
                .unwrap_or_else(|e| panic!("{q} (parallelism {p}): {e}"));
                assert_eq!(par, seq, "{q} at parallelism {p}");
            }
        }
    }

    #[test]
    fn query_stats_count_morsels() {
        let catalog = big_catalog();
        let seq = ExecContext::new(catalog.clone(), Arc::new(UdfRegistry::new()))
            .with_parallelism(1);
        let (_, stats) =
            run_sql_with_stats("SELECT k, COUNT(*) AS n FROM facts GROUP BY k", &seq).unwrap();
        assert_eq!(stats.aggregate.morsels, 1);
        assert_eq!(stats.aggregate.max_threads, 1);
        let par = ExecContext::new(catalog, Arc::new(UdfRegistry::new())).with_parallelism(4);
        let (_, stats) =
            run_sql_with_stats("SELECT k, COUNT(*) AS n FROM facts GROUP BY k", &par).unwrap();
        assert_eq!(stats.aggregate.max_threads, 4); // 40 000 rows / 4096 ≥ 4
        assert_eq!(stats.aggregate.morsels, 4);
        let report = stats.report();
        assert!(report.contains("morsels"), "{report}");
    }
}
